//! The operator config module (paper §5.3.1): "Each operator is
//! configured by read/write access (also over ECI) to a config module,
//! e.g. to set query parameters or to load a regex. This communication is
//! not on the critical path of the workload."
//!
//! Registers are 8-byte words in a 128-byte-aligned window, accessed via
//! the ECI I/O VCs (`MsgKind::IoRead` / `IoWrite`).

use std::collections::BTreeMap;

/// Canonical register offsets.
pub mod regs {
    /// f32 bits of the SELECT X parameter.
    pub const SELECT_X: u64 = 0x00;
    /// f32 bits of the SELECT Y parameter.
    pub const SELECT_Y: u64 = 0x08;
    /// scan trigger / status: write 1 to arm, reads 1 while scanning.
    pub const SCAN_CTL: u64 = 0x10;
    /// results produced so far (read-only).
    pub const RESULT_COUNT: u64 = 0x18;
    /// regex upload window base (the DFA table is written 8 bytes at a
    /// time; the real hardware streams it into BRAM).
    pub const REGEX_BASE: u64 = 0x100;
}

/// A bank of 8-byte config registers.
#[derive(Default)]
pub struct ConfigBlock {
    regs: BTreeMap<u64, u64>,
    /// I/O operations served (all off the critical path).
    pub reads: u64,
    pub writes: u64,
}

impl ConfigBlock {
    pub fn new() -> ConfigBlock {
        Self::default()
    }

    pub fn read(&mut self, offset: u64) -> u64 {
        self.reads += 1;
        self.regs.get(&(offset & !7)).copied().unwrap_or(0)
    }

    pub fn write(&mut self, offset: u64, value: u64) {
        self.writes += 1;
        self.regs.insert(offset & !7, value);
    }

    pub fn select_params(&self) -> (f32, f32) {
        (
            f32::from_bits(self.regs.get(&regs::SELECT_X).copied().unwrap_or(0) as u32),
            f32::from_bits(self.regs.get(&regs::SELECT_Y).copied().unwrap_or(0) as u32),
        )
    }

    pub fn set_select_params(&mut self, x: f32, y: f32) {
        self.write(regs::SELECT_X, x.to_bits() as u64);
        self.write(regs::SELECT_Y, y.to_bits() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registers_round_trip() {
        let mut c = ConfigBlock::new();
        c.write(regs::SELECT_X, 42);
        assert_eq!(c.read(regs::SELECT_X), 42);
        assert_eq!(c.read(regs::SELECT_Y), 0);
        assert_eq!(c.reads, 2);
        assert_eq!(c.writes, 1);
    }

    #[test]
    fn unaligned_access_hits_the_containing_word() {
        let mut c = ConfigBlock::new();
        c.write(0x08, 7);
        assert_eq!(c.read(0x0C), 7);
    }

    #[test]
    fn select_params_encode_as_f32_bits() {
        let mut c = ConfigBlock::new();
        c.set_select_params(0.25, -3.5);
        let (x, y) = c.select_params();
        assert_eq!(x, 0.25);
        assert_eq!(y, -3.5);
    }
}
