//! Open-loop arrival processes.
//!
//! The closed-loop generator (`dcs::loadgen`) can never overload the
//! directory: each client waits for its previous operation, so offered
//! load self-throttles to the service rate. Open-loop arrivals decouple
//! the two — operations arrive on a clock of their own, and when the
//! offered rate exceeds capacity the backlog (and therefore latency)
//! grows without bound. That is the regime the latency-vs-load knee of
//! `harness::fig_loadcurve` characterizes.
//!
//! Two processes, both driven by the deterministic [`Rng`]:
//! [`ArrivalKind::Deterministic`] spaces arrivals exactly `1/rate`
//! apart (isolates queueing caused by *service* variability), while
//! [`ArrivalKind::Poisson`] draws exponential gaps (memoryless traffic,
//! the standard open-system model and the harsher of the two on tails).

use crate::sim::rng::Rng;
use crate::sim::time::Duration;

/// Shape of the inter-arrival distribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Fixed gaps of exactly `1/rate`.
    Deterministic,
    /// Exponential gaps with mean `1/rate` (Poisson arrivals).
    Poisson,
}

impl ArrivalKind {
    /// CLI spelling -> kind (`fixed`/`deterministic`, `poisson`/`exp`).
    pub fn parse(s: &str) -> Option<ArrivalKind> {
        match s {
            "fixed" | "deterministic" => Some(ArrivalKind::Deterministic),
            "poisson" | "exp" | "exponential" => Some(ArrivalKind::Poisson),
            _ => None,
        }
    }
}

/// An arrival clock at a configured offered rate.
pub struct Arrivals {
    kind: ArrivalKind,
    mean_gap_ps: f64,
    rng: Rng,
}

impl Arrivals {
    pub fn new(kind: ArrivalKind, rate_per_s: f64, rng: Rng) -> Arrivals {
        assert!(rate_per_s > 0.0 && rate_per_s.is_finite(), "bad offered rate {rate_per_s}");
        Arrivals { kind, mean_gap_ps: 1e12 / rate_per_s, rng }
    }

    pub fn rate_per_s(&self) -> f64 {
        1e12 / self.mean_gap_ps
    }

    /// Gap to the next arrival (at least 1 ps, so time always advances).
    pub fn next_gap(&mut self) -> Duration {
        let ps = match self.kind {
            ArrivalKind::Deterministic => self.mean_gap_ps,
            ArrivalKind::Poisson => self.rng.exp(self.mean_gap_ps),
        };
        Duration::from_ps((ps.round() as u64).max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_gaps_are_exact() {
        let mut a = Arrivals::new(ArrivalKind::Deterministic, 1e9, Rng::new(1));
        for _ in 0..10 {
            assert_eq!(a.next_gap(), Duration::from_ns(1));
        }
        assert!((a.rate_per_s() - 1e9).abs() < 1.0);
    }

    #[test]
    fn poisson_gaps_have_the_right_mean() {
        let mut a = Arrivals::new(ArrivalKind::Poisson, 1e9, Rng::new(7));
        let n = 100_000;
        let sum: u64 = (0..n).map(|_| a.next_gap().ps()).sum();
        let mean = sum as f64 / n as f64;
        // mean gap 1000 ps, ±2%
        assert!((mean - 1000.0).abs() < 20.0, "mean gap {mean} ps");
    }

    #[test]
    fn gaps_never_collapse_to_zero() {
        let mut a = Arrivals::new(ArrivalKind::Poisson, 1e12, Rng::new(11));
        for _ in 0..10_000 {
            assert!(a.next_gap().ps() >= 1);
        }
    }

    #[test]
    fn parse_spellings() {
        assert_eq!(ArrivalKind::parse("fixed"), Some(ArrivalKind::Deterministic));
        assert_eq!(ArrivalKind::parse("poisson"), Some(ArrivalKind::Poisson));
        assert_eq!(ArrivalKind::parse("exp"), Some(ArrivalKind::Poisson));
        assert_eq!(ArrivalKind::parse("bogus"), None);
    }
}
