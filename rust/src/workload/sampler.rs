//! Traffic sampling shared by the open-loop host and the multi-node
//! fabric: per-class address windows, the rate-weight CDF, and the
//! popularity samplers. Extracted from [`super::openloop`] so that
//! every driver draws traffic with the identical RNG discipline —
//! the same fork tags at construction and the same draw order per
//! arrival — which is what lets the 1-node fabric reproduce the
//! open-loop host's event stream bit for bit.

use crate::dcs::loadgen::MixConfig;
use crate::sim::rng::Rng;

use super::scenario::{Popularity, Scenario};
use super::zipf::Zipf;

/// Per-class runtime: address window, samplers, weight CDF entry.
pub struct ClassRt {
    pub name: String,
    /// First line of this class's window (windows sit back to back).
    pub base: u64,
    pub lines: u64,
    pub mix: MixConfig,
    pub popularity: Popularity,
    zipf: Option<Zipf>,
    /// Rank -> line-offset scatter for Zipf classes.
    perm: Vec<u32>,
    /// Inclusive upper bound of this class in the rate-weight CDF.
    pub weight_cum: u64,
}

/// What one sampled arrival does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SampleKind {
    Read,
    Write,
    Chase { hops: u64 },
}

/// The stationary scenario sampler: draw (class, kind, line) per
/// arrival.
pub struct TrafficSampler {
    classes: Vec<ClassRt>,
    weight_total: u64,
}

impl TrafficSampler {
    /// Build the per-class runtimes: weight CDF, Zipf sampler, rank
    /// scatter. Zipf classes fork their scatter stream from `master`
    /// with tag `100 + class_index` — the historical open-loop fork
    /// order, which downstream digests depend on.
    pub fn build(scenario: &Scenario, master: &mut Rng) -> TrafficSampler {
        let mut classes = Vec::with_capacity(scenario.classes.len());
        let mut base = 0u64;
        let mut cum = 0u64;
        for (i, c) in scenario.classes.iter().enumerate() {
            cum += c.rate_weight as u64;
            let (zipf, perm) = match c.popularity {
                Popularity::Uniform => (None, Vec::new()),
                Popularity::Zipf { theta } => {
                    let mut r = master.fork(100 + i as u64);
                    let (z, p) = Zipf::scattered(c.footprint_lines, theta, &mut r);
                    (Some(z), p)
                }
            };
            classes.push(ClassRt {
                name: c.name.clone(),
                base,
                lines: c.footprint_lines,
                mix: c.mix,
                popularity: c.popularity,
                zipf,
                perm,
                weight_cum: cum,
            });
            base += c.footprint_lines;
        }
        TrafficSampler { classes, weight_total: cum }
    }

    pub fn classes(&self) -> &[ClassRt] {
        &self.classes
    }

    pub fn weight_total(&self) -> u64 {
        self.weight_total
    }

    /// Draw one arrival: (class index, op kind, absolute line index in
    /// the scenario region). Exactly three draw sites on `rng`, in the
    /// historical order — weight CDF, mix, popularity — so a host that
    /// swaps in this sampler replays the identical stream.
    pub fn sample(&self, rng: &mut Rng) -> (u16, SampleKind, u64) {
        let t = rng.below(self.weight_total);
        let ci = self
            .classes
            .iter()
            .position(|c| t < c.weight_cum)
            .expect("weight CDF covers every draw");
        let cls = &self.classes[ci];
        let mix = cls.mix;
        let m = rng.below(mix.total() as u64) as u32;
        let kind = if m < mix.reads {
            SampleKind::Read
        } else if m < mix.reads + mix.writes {
            SampleKind::Write
        } else {
            SampleKind::Chase { hops: mix.chase_hops.max(1) }
        };
        let off = match cls.popularity {
            Popularity::Uniform => rng.below(cls.lines),
            Popularity::Zipf { .. } => {
                let rank = cls.zipf.as_ref().expect("zipf sampler built at init").sample(rng);
                cls.perm[rank as usize] as u64
            }
        };
        (ci as u16, kind, cls.base + off)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_stays_in_class_window_and_covers_all_classes() {
        let sc = Scenario::preset("tenants", 1 << 12, 0.9).expect("preset");
        let mut master = Rng::new(0xABCD);
        let s = TrafficSampler::build(&sc, &mut master);
        assert_eq!(s.classes().len(), sc.classes.len());
        assert_eq!(s.weight_total(), sc.total_weight());
        let mut rng = Rng::new(7);
        let mut seen = vec![false; s.classes().len()];
        for _ in 0..5_000 {
            let (ci, _, line) = s.sample(&mut rng);
            let c = &s.classes()[ci as usize];
            assert!(line >= c.base && line < c.base + c.lines, "draw outside class window");
            seen[ci as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "every class must draw under its weight");
    }

    #[test]
    fn sampler_is_seed_stable() {
        let sc = Scenario::preset("hot-kvs", 1 << 12, 0.9).expect("preset");
        let draw = |seed: u64| {
            let mut master = Rng::new(seed);
            let s = TrafficSampler::build(&sc, &mut master);
            let mut rng = Rng::new(99);
            (0..64).map(|_| s.sample(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(draw(0xEC1), draw(0xEC1), "same seed, same stream");
    }
}
