//! The open-loop, scenario-driven traffic engine.
//!
//! Where `dcs::loadgen` closes the loop (M clients, one outstanding op
//! each, next op on completion — measures *sustained* service rate),
//! this engine opens it: operations arrive on their own clock
//! ([`Arrivals`], deterministic or Poisson) at a configured offered
//! rate, drawn per arrival from a [`Scenario`]'s traffic classes
//! (class → op kind → line, with optional Zipf-skewed popularity).
//! Offered load is therefore independent of the directory's ability to
//! keep up, which is what makes the latency-vs-load knee of
//! `harness::fig_loadcurve` measurable at all.
//!
//! Admission is credit-accurate: every generated message crosses a real
//! [`FramedIngress`] — VC arbitration, per-VC credits, frame
//! sequencing, serial-lane occupancy — in *both* directions, and the
//! request-direction credit is held until the owning directory slice
//! consumes the message from its ingress FIFO ([`Dcs::enqueue_frame`] /
//! [`SliceService::Done`]). Overload therefore shows up exactly as it
//! would on the wire: credits exhaust, the transmit queue grows, and
//! queueing delay climbs the latency distribution from p999 down.
//!
//! Clients come in two styles, per [`OpenLoopConfig::cached`]:
//! a *caching* client behaves like the closed-loop one (shared
//! LLC-sized cache; hot lines are absorbed before the directory), and a
//! *streaming* (DMA-like) client voluntarily releases every line after
//! use — each completed access returns the line to `I` with a
//! `VolDowngrade`, so every operation reaches the directory. Streaming
//! is the default: it is the accelerator-offload traffic shape, and the
//! one where Zipf skew stresses single-slice hot spots instead of the
//! client cache.

use std::collections::VecDeque;

use crate::agents::cache::Cache;
use crate::agents::dram::{Dram, MemStore};
use crate::agents::home::HomeEffect;
use crate::agents::remote::{Access, RemoteAgent, RemoteEffect};
use crate::config::SystemSpec;
use crate::ctrl::{Controller, Phase, ReconfigEvent, ReconfigKind, ReconfigReport, TransitionRecord};
use crate::dcs::{Dcs, DcsConfig, SliceService};
use crate::machine::MachineConfig;
use crate::memctl::KvsService;
use crate::obs::{FlightKind, Obs, ObsConfig, ObsReport, Registry, Stage};
use crate::proto::messages::{LineAddr, Message, MsgKind};
use crate::proto::spec::generate_remote;
use crate::proto::states::Node;
use crate::proto::transitions::reference_transitions;
use crate::rustc_hash::{FxHashMap as HashMap, FxHashSet as HashSet};
use crate::sim::engine::Engine;
use crate::sim::rng::{stream_seed, Rng};
use crate::sim::stats::{Counters, Histogram};
use crate::sim::time::{Duration, Time};
use crate::transport::{Control, Frame, FramedIngress, VcId};

use super::arrival::{ArrivalKind, Arrivals};
use super::sampler::{SampleKind, TrafficSampler};
use super::scenario::Scenario;

/// Open-loop engine parameters (the traffic itself comes from a
/// [`Scenario`]; the node shape comes from the embedded
/// [`MachineConfig`] — link credits and framing, slice pipeline, FPGA
/// DRAM — so scenario runs and machine runs exercise the same
/// directory).
#[derive(Clone, Copy, Debug)]
pub struct OpenLoopConfig {
    /// Offered arrival rate, operations/second.
    pub rate_per_s: f64,
    pub arrivals: ArrivalKind,
    /// Total arrivals to generate.
    pub ops: u64,
    /// `true`: caching client (loadgen-style shared cache).
    /// `false` (default): streaming client — every line is voluntarily
    /// released after use, so every operation reaches the directory.
    pub cached: bool,
    /// `true`: the directory runs *cached* slices — each slice carries a
    /// partition of the machine's home-cache budget
    /// (`MachineConfig::dcs_cached_config`), so repeat shared reads are
    /// served slice-locally instead of from FPGA DRAM. Independent of
    /// `cached` (client side); the interesting streaming configurations
    /// are exactly the ones where only the home side caches.
    pub home_cached: bool,
    /// Client-side processing between dependent chase hops.
    pub hop_think: Duration,
    /// KVS engine-pool size backing chase resolution at the home.
    pub kvs_engines: usize,
    pub seed: u64,
    /// Node wiring: link (credits/framing/phys), `home_proc` slice
    /// pipeline, control-path latency, FPGA DRAM.
    pub machine: MachineConfig,
}

impl Default for OpenLoopConfig {
    fn default() -> OpenLoopConfig {
        OpenLoopConfig {
            rate_per_s: 4e6,
            arrivals: ArrivalKind::Poisson,
            ops: 20_000,
            cached: false,
            home_cached: false,
            hop_think: Duration::from_ns(2),
            kvs_engines: 8,
            seed: 0x0C3A,
            machine: MachineConfig::enzian_eci(),
        }
    }
}

/// Per-traffic-class latency breakdown (arrival to completion, ps).
#[derive(Clone, Debug)]
pub struct ClassLatency {
    pub class: String,
    pub completed: u64,
    pub lat: Histogram,
}

impl ClassLatency {
    pub fn p50_ns(&self) -> f64 {
        self.lat.p50() as f64 / 1000.0
    }
    pub fn p99_ns(&self) -> f64 {
        self.lat.p99() as f64 / 1000.0
    }
    pub fn p999_ns(&self) -> f64 {
        self.lat.p999() as f64 / 1000.0
    }
}

/// Results of one open-loop run.
#[derive(Debug)]
pub struct OpenLoopReport {
    pub scenario: String,
    /// Configured arrival rate.
    pub offered_per_s: f64,
    /// Completions over total simulated time (≈ offered below the knee,
    /// ≈ service capacity above it).
    pub delivered_per_s: f64,
    pub completed: u64,
    pub sim_time: Time,
    /// Per-operation latency, arrival (admission) to completion, ps —
    /// transmit-queue wait included, which is the open-loop point.
    pub lat: Histogram,
    /// The same latency, broken down per traffic class (one entry per
    /// scenario class, in scenario order).
    pub per_class: Vec<ClassLatency>,
    /// Fraction of transmitted link frames that were useful (accepted
    /// in sequence), both directions merged: 1.0 on a clean link,
    /// sinking as replays burn bandwidth under fault injection.
    pub frame_goodput: f64,
    pub per_slice_served: Vec<u64>,
    pub per_slice_occupancy: Vec<f64>,
    /// Hot-spot skew (max/mean) of per-slice served load.
    pub served_skew: f64,
    /// Hot-spot skew (max/mean) of per-slice pipeline occupancy.
    pub occupancy_skew: f64,
    /// Request-direction pump invocations starved by credits.
    pub credit_stalls: u64,
    /// High-water mark of the request-direction transmit queue.
    pub peak_tx_queue: usize,
    /// High-water mark of launched-but-unserviced request frames across
    /// all VCs. Credits are held until slice service (batched or not),
    /// so this never exceeds the per-VC budget times the VCs in use.
    pub peak_in_flight: u32,
    /// Simulator events dispatched (host-side cost; the selfperf metric).
    pub events: u64,
    pub counters: Counters,
    /// What the control plane did (present iff the run was configured
    /// with [`OpenLoop::with_reconfig`]). Per-slice report columns
    /// (`per_slice_served`, occupancy) cover the *final* shape only —
    /// counters absorbed from retired directory instances live in
    /// `counters`.
    pub reconfig: Option<ReconfigReport>,
}

impl OpenLoopReport {
    pub fn p50_ns(&self) -> f64 {
        self.lat.p50() as f64 / 1000.0
    }
    pub fn p99_ns(&self) -> f64 {
        self.lat.p99() as f64 / 1000.0
    }
    pub fn p999_ns(&self) -> f64 {
        self.lat.p999() as f64 / 1000.0
    }
}

#[derive(Clone, Copy, Debug)]
enum OpKind {
    Read,
    Write,
    /// Remaining dependent hops.
    Chase { left: u64 },
}

/// One in-flight operation (slots are recycled through a free list —
/// open-loop concurrency is unbounded by design).
#[derive(Clone, Copy, Debug)]
struct OpCtx {
    kind: OpKind,
    addr: LineAddr,
    started: Time,
    active: bool,
    /// Index of the traffic class that drew this operation.
    class: u16,
}

enum Ev {
    /// Next open-loop arrival.
    Arrive,
    /// Issue (or retry after a fill) the op in this slot.
    Step(u32),
    /// Frame lands at the home/cpu end of its direction.
    LandHome(Box<Frame>),
    LandCpu(Box<Frame>),
    /// A home-side message (response/fwd) is ready for the return link.
    HomeSend(Box<Message>),
    /// Ack/nack control frames, applied after the control-path latency.
    CtlHome(Control),
    CtlCpu(Control),
    /// Receiver freed a buffer slot on this VC.
    CreditHome(VcId),
    CreditCpu(VcId),
    /// Service attempt on a dcs slice.
    Poll(u32),
    /// Retransmit-timeout check on a direction (rel links only): with
    /// frames unacked and no ack progress since arming, the sender
    /// rewinds its replay buffers (tail-loss recovery).
    RetxHome,
    RetxCpu,
    /// Delayed-ack flush on a direction's receiver (rel links only):
    /// ack debt that found no reverse frame to piggyback on goes out as
    /// explicit controls, so a quiet link never mistakes ack delay for
    /// loss.
    AckFlushHome,
    AckFlushCpu,
    /// Scripted reconfiguration event `i` fires (begin quiescing).
    Reconfig(u32),
    /// Control-plane poll: is the data plane quiet yet? Re-armed every
    /// `ctrl_latency` until it is, then the handoff executes.
    QuiesceCheck,
}

/// The open-loop engine: arrival clock + scenario samplers on one side,
/// the sliced directory behind real link framing on the other.
pub struct OpenLoop {
    cfg: OpenLoopConfig,
    scenario_name: String,
    eng: Engine<Ev>,
    dcs: Dcs,
    mem: MemStore,
    dram: Dram,
    kvs: KvsService,
    remote: RemoteAgent,
    cache: Cache,
    /// Request direction: generator -> directory (credits held until a
    /// slice consumes the message).
    to_home: FramedIngress,
    /// Response direction: directory -> generator (the cpu sinks
    /// responses at arrival).
    to_cpu: FramedIngress,
    arrivals: Arrivals,
    traffic_rng: Rng,
    sampler: TrafficSampler,
    region_lines: u64,
    ops: Vec<OpCtx>,
    free: Vec<u32>,
    /// Op slots parked per line awaiting a fill.
    waiters: HashMap<LineAddr, Vec<u32>>,
    /// Outstanding request ids belonging to chase hops (resolved through
    /// the KVS engine pool at the home).
    chase_ids: HashSet<u32>,
    issued: u64,
    completed: u64,
    /// Latest time a Poll is already scheduled per slice (dedup: under
    /// deep overload every frame arrival would otherwise schedule its
    /// own redundant poll chain — quadratic event count).
    poll_at: Vec<Time>,
    /// High-water mark of request-direction in-flight frames.
    peak_in_flight: u32,
    /// A retransmit check is already scheduled per direction (0 = home,
    /// 1 = cpu).
    retx_pending: [bool; 2],
    /// Ack progress seen when the pending check was armed.
    retx_seen_acked: [u64; 2],
    /// A delayed-ack flush is already scheduled per direction.
    ack_flush_pending: [bool; 2],
    /// Reused launch buffer for the link pumps (they run on every
    /// send/credit/control event; a fresh Vec each time is pure churn).
    scratch: Vec<(Time, Frame)>,
    /// Reused receive buffers for frame deliveries (a selective-repeat
    /// arrival can release several buffered frames at once).
    rx_frames: Vec<Frame>,
    rx_ctls: Vec<Control>,
    lat: Histogram,
    /// Per-class latency, parallel to `classes`.
    class_lat: Vec<Histogram>,
    counters: Counters,
    /// Passive observability (span tracing, telemetry ticker). Lives
    /// outside [`OpenLoopConfig`] — the config stays `Copy` and
    /// digest-relevant; obs never perturbs the simulation.
    obs: Option<Obs>,
    /// The control plane (present iff scripted reconfigurations were
    /// attached). Owns the canonical current-shape [`SystemSpec`].
    ctrl: Option<Box<Controller>>,
    /// Arrivals parked while quiescing, FIFO, stamped with their
    /// *original* arrival times (the quiesce stall is real latency).
    parked: VecDeque<Time>,
    /// `(completion ps, latency ps)` per completed op — the
    /// fig_reconfig dip timeline. Only recorded when `ctrl` is on.
    timeline: Vec<(u64, u64)>,
}

impl OpenLoop {
    pub fn new(cfg: OpenLoopConfig, scenario: &Scenario, slices: usize) -> OpenLoop {
        assert!(cfg.ops > 0, "need at least one arrival");
        assert!(slices > 0, "need at least one slice");
        let mut master = Rng::new(cfg.seed);
        let spec = reference_transitions();

        // Backing store: class windows back to back, pointer chains over
        // the whole region (chases may wander across windows).
        let region_lines = scenario.total_lines();
        assert!(region_lines >= 2, "scenario region too small");
        let mut mem = MemStore::new(LineAddr(0), (region_lines as usize) * 128);
        let mut chain: Vec<u64> = (0..region_lines).collect();
        master.shuffle(&mut chain);
        for i in 0..region_lines {
            let mut line = [0u8; 128];
            line[0..8].copy_from_slice(&i.to_le_bytes());
            line[120..128].copy_from_slice(&chain[i as usize].to_le_bytes());
            mem.write_line(LineAddr(i), &line);
        }

        // Per-class runtime: weight CDF, Zipf sampler, rank scatter
        // (forks `master` with the historical tags — digest-relevant).
        let sampler = TrafficSampler::build(scenario, &mut master);
        let n_classes = sampler.classes().len();

        let dcs_cfg = if cfg.home_cached {
            cfg.machine.dcs_cached_config(slices)
        } else {
            cfg.machine.dcs_config(slices)
        };

        OpenLoop {
            scenario_name: scenario.name.clone(),
            eng: Engine::new(),
            dcs: Dcs::with_reference_rules(dcs_cfg),
            mem,
            dram: Dram::new(cfg.machine.fpga_dram),
            kvs: KvsService::new(cfg.kvs_engines),
            remote: RemoteAgent::new(
                Node::Remote,
                generate_remote(&spec),
                LineAddr(0),
                region_lines,
            ),
            // the machine's LLC geometry, so `--cached` runs are
            // comparable to machine-model runs on the same config; in
            // streaming mode lines are released right after use and the
            // cache stays nearly empty regardless of size
            cache: Cache::new(cfg.machine.cpu.llc_bytes, cfg.machine.cpu.llc_ways),
            // both link directions draw independent fault streams via
            // `stream_seed` (kind 1 = node↔client links, idx 0 here);
            // the fabric derives its node-0 links identically, which is
            // what keeps a 1-node fabric bit-identical to this cell
            to_home: match cfg.machine.rel {
                Some(mut rc) => {
                    rc.faults.seed = stream_seed(rc.faults.seed, 1, 0, 0);
                    FramedIngress::with_rel(cfg.machine.link, Node::Remote, master.fork(2), rc)
                }
                None => FramedIngress::new(cfg.machine.link, Node::Remote, master.fork(2)),
            },
            to_cpu: match cfg.machine.rel {
                Some(mut rc) => {
                    rc.faults.seed = stream_seed(rc.faults.seed, 1, 0, 1);
                    FramedIngress::with_rel(cfg.machine.link, Node::Home, master.fork(3), rc)
                }
                None => FramedIngress::new(cfg.machine.link, Node::Home, master.fork(3)),
            },
            arrivals: Arrivals::new(cfg.arrivals, cfg.rate_per_s, master.fork(4)),
            traffic_rng: master.fork(5),
            sampler,
            region_lines,
            ops: Vec::new(),
            free: Vec::new(),
            waiters: HashMap::default(),
            chase_ids: HashSet::default(),
            issued: 0,
            completed: 0,
            poll_at: vec![Time::ZERO; slices],
            peak_in_flight: 0,
            retx_pending: [false; 2],
            retx_seen_acked: [0; 2],
            ack_flush_pending: [false; 2],
            scratch: Vec::new(),
            rx_frames: Vec::new(),
            rx_ctls: Vec::new(),
            lat: Histogram::new(),
            class_lat: vec![Histogram::new(); n_classes],
            counters: Counters::new(),
            obs: None,
            ctrl: None,
            parked: VecDeque::new(),
            timeline: Vec::new(),
            cfg,
        }
    }

    /// Attach passive observability (span tracing and/or the telemetry
    /// ticker) before running; collect results through
    /// [`OpenLoop::run_observed`] or [`OpenLoop::run_settled_observed`].
    pub fn with_obs(mut self, ocfg: &ObsConfig) -> OpenLoop {
        if ocfg.enabled() {
            self.obs = Some(Obs::new(ocfg));
        }
        self
    }

    /// Attach a scripted live-reconfiguration sequence (see
    /// [`crate::ctrl`]). The controller seeds its canonical "current
    /// shape" [`SystemSpec`] from this engine's own configuration;
    /// every transition mutates that spec and rebuilds the affected
    /// plane from it. An empty script is a no-op — the run stays
    /// bit-identical to an unscripted one.
    pub fn with_reconfig(mut self, events: Vec<ReconfigEvent>) -> OpenLoop {
        if events.is_empty() {
            return self;
        }
        let spec = SystemSpec::of_openloop(self.cfg, self.dcs.slices());
        self.ctrl = Some(Box::new(Controller::new(spec, events)));
        self
    }

    /// Run until every arrival has completed, then report.
    pub fn run(mut self) -> OpenLoopReport {
        self.run_to_completion();
        self.report()
    }

    /// Run to completion, then *settle*: process every event still
    /// queued (trailing releases, replays, ack and credit returns) so
    /// the directory state is final, and return the report plus a
    /// digest of that state (per-line directory states + backing-store
    /// bytes). Two runs with matching digests ended in bit-identical
    /// protocol state — the loss-transparency observable: fault
    /// injection may change *when*, never *what*.
    pub fn run_settled(mut self) -> (OpenLoopReport, u64) {
        let digest = self.settle();
        (self.report(), digest)
    }

    /// [`OpenLoop::run`] with observability attached: the report plus
    /// everything obs collected (waterfall, telemetry, registry).
    pub fn run_observed(mut self) -> (OpenLoopReport, ObsReport) {
        self.run_to_completion();
        let obs = self.finish_obs();
        (self.report(), obs)
    }

    /// [`OpenLoop::run_settled`] with observability attached: report,
    /// settled-state digest, and the obs report. The digest is computed
    /// exactly as in the unobserved path — the obs transparency tests
    /// compare the two directly.
    pub fn run_settled_observed(mut self) -> (OpenLoopReport, u64, ObsReport) {
        let digest = self.settle();
        let obs = self.finish_obs();
        (self.report(), digest, obs)
    }

    fn settle(&mut self) -> u64 {
        self.run_to_completion();
        while let Some((_, ev)) = self.eng.pop() {
            self.dispatch(ev);
            self.obs_tick();
        }
        self.state_digest()
    }

    fn run_to_completion(&mut self) {
        if let Some(c) = &self.ctrl {
            let fire: Vec<(u32, Duration)> =
                c.events.iter().enumerate().map(|(i, e)| (i as u32, e.at)).collect();
            for (i, at) in fire {
                self.eng.schedule(at, Ev::Reconfig(i));
            }
        }
        self.eng.schedule(Duration::ZERO, Ev::Arrive);
        while self.completed < self.cfg.ops {
            let Some((_, ev)) = self.eng.pop() else {
                panic!(
                    "open-loop deadlock: {} of {} ops complete, {} queued at dcs, {} at tx",
                    self.completed,
                    self.cfg.ops,
                    self.dcs.pending(),
                    self.to_home.queued()
                );
            };
            self.dispatch(ev);
            self.obs_tick();
        }
    }

    /// Opportunistic telemetry tick, called after every dispatched
    /// event: one cheap check when telemetry is off or not due; on a due
    /// tick the registry is refreshed from the live counter surfaces
    /// first. Purely observational — reads state, schedules nothing.
    fn obs_tick(&mut self) {
        let now = self.eng.now();
        if !self.obs.as_ref().is_some_and(|o| o.tick_due(now)) {
            return;
        }
        let mut obs = self.obs.take().expect("checked above");
        self.refresh_registry(&mut obs.registry);
        if let Some(sp) = &obs.spans {
            obs.registry.gauge("obs.live_spans", sp.live_spans() as f64);
        }
        obs.tick(now);
        self.obs = Some(obs);
    }

    /// Absorb every live counter surface into the unified registry and
    /// refresh the instantaneous gauges (queue depths, credit occupancy,
    /// OOO-buffer depth, effective RTO).
    fn refresh_registry(&self, reg: &mut Registry) {
        reg.begin_refresh();
        reg.absorb("workload", &self.counters);
        reg.set("workload.issued", self.issued);
        reg.set("workload.completed", self.completed);
        reg.set("workload.kvs_lookups", self.kvs.served);
        // counter continuity across control-plane rebuilds: the live
        // directory's counters plus everything absorbed from retired
        // instances
        let mut dc = self.dcs.counters();
        if let Some(c) = &self.ctrl {
            for (k, v) in c.carried.iter() {
                dc.add(k, v);
            }
        }
        reg.absorb("dcs", &dc);
        self.dcs.observe_gauges("dcs", reg);
        self.to_home.observe("ingress.to_home", reg);
        self.to_cpu.observe("ingress.to_cpu", reg);
        if let Some(mut s) = self.to_home.rel_stats() {
            if let Some(s2) = self.to_cpu.rel_stats() {
                s.merge(&s2);
            }
            reg.absorb_rel("rel", &s);
        }
        if let Some(c) = &self.ctrl {
            reg.gauge("ctrl.phase", c.quiescing() as u8 as f64);
            reg.gauge("ctrl.parked", self.parked.len() as f64);
            reg.set("ctrl.transitions", c.records.len() as u64);
        }
    }

    /// Final registry refresh, span seal, and report extraction.
    fn finish_obs(&mut self) -> ObsReport {
        let mut obs = self.obs.take().expect("attach obs with with_obs first");
        self.refresh_registry(&mut obs.registry);
        obs.tick(self.eng.now());
        obs.finish()
    }

    /// FNV-1a over every line's directory state and backing-store
    /// bytes (see [`OpenLoop::run_settled`]).
    fn state_digest(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat = |h: &mut u64, b: u8| {
            *h ^= b as u64;
            *h = h.wrapping_mul(FNV_PRIME);
        };
        for i in 0..self.region_lines {
            let addr = LineAddr(i);
            for b in format!("{:?}", self.dcs.state_of(addr)).bytes() {
                eat(&mut h, b);
            }
            for &b in self.mem.read_line(addr).iter() {
                eat(&mut h, b);
            }
        }
        h
    }

    fn dispatch(&mut self, ev: Ev) {
        match ev {
            Ev::Arrive => self.arrive(),
            Ev::Step(s) => self.step(s),
            Ev::LandHome(f) => self.land_home(f),
            Ev::LandCpu(f) => self.land_cpu(f),
            Ev::HomeSend(m) => {
                self.to_cpu.offer(*m);
                self.pump_cpu();
            }
            Ev::CtlHome(c) => {
                let now = self.eng.now();
                self.to_home.on_control(now, c);
                self.pump_home();
            }
            Ev::CtlCpu(c) => {
                let now = self.eng.now();
                self.to_cpu.on_control(now, c);
                self.pump_cpu();
            }
            Ev::CreditHome(vc) => {
                self.to_home.credit_return(vc);
                self.pump_home();
            }
            Ev::CreditCpu(vc) => {
                self.to_cpu.credit_return(vc);
                self.pump_cpu();
            }
            Ev::Poll(s) => self.pump_slice(s as usize),
            Ev::RetxHome => self.on_retx(0),
            Ev::RetxCpu => self.on_retx(1),
            Ev::AckFlushHome => self.on_ack_flush(0),
            Ev::AckFlushCpu => self.on_ack_flush(1),
            Ev::Reconfig(i) => self.ctrl_begin(i as usize),
            Ev::QuiesceCheck => self.ctrl_check(),
        }
    }

    /// Delayed-ack flush: debt the piggyback path did not consume in
    /// time goes out as explicit cumulative-ack controls.
    fn on_ack_flush(&mut self, dir: usize) {
        self.ack_flush_pending[dir] = false;
        let ctrl = self.cfg.machine.ctrl_latency;
        loop {
            let ing = if dir == 0 { &mut self.to_home } else { &mut self.to_cpu };
            let Some((vc, seq)) = ing.take_piggy_ack() else { break };
            let ctl = Control::VcAck(vc, seq);
            self.eng.schedule(ctrl, if dir == 0 { Ev::CtlHome(ctl) } else { Ev::CtlCpu(ctl) });
        }
    }

    /// Arm the delayed-ack flush for a direction's receiver when it
    /// carries unflushed debt.
    fn arm_ack_flush(&mut self, dir: usize) {
        let ing = if dir == 0 { &self.to_home } else { &self.to_cpu };
        if self.ack_flush_pending[dir] || !ing.rel_has_ack_debt() {
            return;
        }
        self.ack_flush_pending[dir] = true;
        self.eng.schedule(
            crate::transport::rel::ACK_FLUSH_DELAY,
            if dir == 0 { Ev::AckFlushHome } else { Ev::AckFlushCpu },
        );
    }

    /// Retransmit-timeout check on direction `dir` (0 = requests toward
    /// the home, 1 = responses toward the cpu).
    fn on_retx(&mut self, dir: usize) {
        self.retx_pending[dir] = false;
        let ing = if dir == 0 { &mut self.to_home } else { &mut self.to_cpu };
        if ing.rel_unacked() == 0 {
            return;
        }
        if ing.rel_acked() == self.retx_seen_acked[dir] {
            // no ack progress for a full RTO: rewind and replay
            ing.rel_force_replay();
        }
        // pump the resends; the pump re-arms while anything is unacked
        if dir == 0 {
            self.pump_home();
        } else {
            self.pump_cpu();
        }
    }

    /// Arm the retransmit timer for a direction when frames are unacked
    /// and no check is pending.
    fn arm_retx(&mut self, dir: usize) {
        let ing = if dir == 0 { &self.to_home } else { &self.to_cpu };
        let Some(rto) = ing.link.rel_rto() else { return };
        if ing.rel_unacked() == 0 || self.retx_pending[dir] {
            return;
        }
        self.retx_seen_acked[dir] = ing.rel_acked();
        self.retx_pending[dir] = true;
        self.eng.schedule(rto, if dir == 0 { Ev::RetxHome } else { Ev::RetxCpu });
    }

    fn report(mut self) -> OpenLoopReport {
        let ctrl = self.ctrl.take();
        let timeline = std::mem::take(&mut self.timeline);
        let sim_time = self.eng.now();
        let n = self.dcs.slices();
        let per_slice_served = self.dcs.per_slice_served();
        let per_slice_occupancy =
            (0..n).map(|s| self.dcs.slice_stats(s).occupancy(sim_time)).collect();
        let served_skew = self.dcs.served_skew();
        let occupancy_skew = self.dcs.occupancy_skew(sim_time);
        let mut counters = self.dcs.counters();
        if let Some(c) = &ctrl {
            // counter continuity: directory instances retired by
            // control-plane rebuilds still count
            for (k, v) in c.carried.iter() {
                counters.add(k, v);
            }
        }
        for (k, v) in self.remote.stats.iter() {
            counters.add(k, v);
        }
        for (k, v) in self.counters.iter() {
            counters.add(k, v);
        }
        counters.add("kvs_lookups", self.kvs.served);
        let frames_sent = |ing: &FramedIngress| match ing.link.rel.as_ref() {
            Some(r) => r.tx.sent,
            None => ing.link.tx.sent,
        };
        counters.add("frames_to_home", frames_sent(&self.to_home));
        counters.add("frames_to_cpu", frames_sent(&self.to_cpu));
        counters.add("home_credit_stalls", self.to_home.credit_stalls);
        let frame_goodput = match self.to_home.rel_stats() {
            Some(mut s) => {
                if let Some(s2) = self.to_cpu.rel_stats() {
                    s.merge(&s2);
                }
                s.add_to(&mut counters);
                s.frame_goodput()
            }
            None => 1.0,
        };
        let per_class = self
            .sampler
            .classes()
            .iter()
            .zip(&self.class_lat)
            .map(|(c, lat)| ClassLatency {
                class: c.name.clone(),
                completed: lat.count(),
                lat: lat.clone(),
            })
            .collect();
        let delivered_per_s = if sim_time.ps() == 0 {
            0.0
        } else {
            self.completed as f64 / sim_time.as_secs()
        };
        OpenLoopReport {
            scenario: self.scenario_name,
            offered_per_s: self.cfg.rate_per_s,
            delivered_per_s,
            completed: self.completed,
            sim_time,
            lat: self.lat,
            per_class,
            frame_goodput,
            per_slice_served,
            per_slice_occupancy,
            served_skew,
            occupancy_skew,
            credit_stalls: self.to_home.credit_stalls,
            peak_tx_queue: self.to_home.peak_queue,
            peak_in_flight: self.peak_in_flight,
            events: self.eng.dispatched,
            counters,
            reconfig: ctrl
                .map(|c| ReconfigReport { transitions: c.records, timeline }),
        }
    }

    // -- arrivals -----------------------------------------------------------

    fn arrive(&mut self) {
        if self.issued + self.parked.len() as u64 >= self.cfg.ops {
            return;
        }
        if self.ctrl.as_ref().is_some_and(|c| c.quiescing()) {
            // park the arrival, but keep the arrival *clock* ticking:
            // the gap sequence (and with it every RNG draw) stays
            // identical to a run that never reconfigured
            self.parked.push_back(self.eng.now());
        } else {
            self.spawn_at(self.eng.now());
        }
        if self.issued + self.parked.len() as u64 < self.cfg.ops {
            let gap = self.arrivals.next_gap();
            self.eng.schedule(gap, Ev::Arrive);
        }
    }

    /// Draw (class, op kind, line) for one arrival and start it.
    /// `started` is the op's arrival time — for a parked-then-released
    /// arrival that is the *original* arrival instant, so the quiesce
    /// stall lands in its measured latency.
    fn spawn_at(&mut self, started: Time) {
        let (ci, kind, line) = self.sampler.sample(&mut self.traffic_rng);
        let kind = match kind {
            SampleKind::Read => OpKind::Read,
            SampleKind::Write => OpKind::Write,
            SampleKind::Chase { hops } => OpKind::Chase { left: hops },
        };
        let ctx = OpCtx {
            kind,
            addr: LineAddr(line),
            started,
            active: true,
            class: ci,
        };
        let slot = match self.free.pop() {
            Some(s) => {
                self.ops[s as usize] = ctx;
                s
            }
            None => {
                self.ops.push(ctx);
                (self.ops.len() - 1) as u32
            }
        };
        self.issued += 1;
        self.step(slot);
    }

    // -- client side --------------------------------------------------------

    /// Offer a client message to the home-bound ingress. The single
    /// admission point for client traffic: the span tracer samples
    /// response-needing coherence requests here (stage `Issue`).
    fn offer_home(&mut self, m: Message) {
        if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
            if let MsgKind::CohReq { op } = &m.kind {
                if op.needs_response() {
                    sp.on_issue(self.eng.now(), m.id.0);
                }
            }
        }
        self.to_home.offer(m);
    }

    /// Issue (or retry after a fill) the access of the op in `slot`.
    fn step(&mut self, slot: u32) {
        let (addr, write, is_chase) = {
            let o = &self.ops[slot as usize];
            debug_assert!(o.active, "step on a completed op slot");
            (o.addr, matches!(o.kind, OpKind::Write), matches!(o.kind, OpKind::Chase { .. }))
        };
        let (acc, fx) = self.remote.local_access(addr, write, &mut self.cache);
        let mut sent = false;
        for e in fx {
            match e {
                RemoteEffect::Send(m) => {
                    if is_chase {
                        if let MsgKind::CohReq { op } = &m.kind {
                            if op.needs_response() {
                                self.chase_ids.insert(m.id.0);
                            }
                        }
                    }
                    self.offer_home(m);
                    sent = true;
                }
                RemoteEffect::Stalled => {}
                RemoteEffect::Filled { .. } => {}
                RemoteEffect::ForeignVictim(_) => self.counters.inc("foreign_victim"),
            }
        }
        if sent {
            self.pump_home();
        }
        match acc {
            Access::Hit => self.access_done(slot),
            Access::Pending => {
                self.waiters.entry(addr).or_default().push(slot);
                if !sent {
                    self.counters.inc("mshr_merged");
                }
            }
        }
    }

    /// The access of the op in `slot` completed (hit or post-fill
    /// retry): advance its state machine.
    fn access_done(&mut self, slot: u32) {
        let now = self.eng.now();
        let (kind, addr) = {
            let o = &self.ops[slot as usize];
            (o.kind, o.addr)
        };
        match kind {
            OpKind::Write => {
                // dirty the line with an observable stamp; the pointer
                // slot at 120..128 is preserved so chase chains survive
                if let Some(e) = self.cache.lookup(addr) {
                    e.data[0..8].copy_from_slice(&now.ps().to_le_bytes());
                }
                self.finish(slot, addr);
            }
            OpKind::Read => self.finish(slot, addr),
            OpKind::Chase { left } => {
                if left <= 1 {
                    self.finish(slot, addr);
                    return;
                }
                // decode the next hop from the bytes actually served
                let data = self
                    .cache
                    .peek(addr)
                    .map(|e| *e.data)
                    .unwrap_or_else(|| self.mem.read_line(addr));
                let ptr = u64::from_le_bytes(data[120..128].try_into().unwrap());
                if !self.cfg.cached {
                    self.release(addr);
                }
                let o = &mut self.ops[slot as usize];
                o.addr = LineAddr(ptr % self.region_lines);
                o.kind = OpKind::Chase { left: left - 1 };
                let think = self.cfg.hop_think;
                self.eng.schedule(think, Ev::Step(slot));
            }
        }
    }

    fn finish(&mut self, slot: u32, addr: LineAddr) {
        let now = self.eng.now();
        let started = self.ops[slot as usize].started;
        let d = now.since(started).ps();
        self.lat.record(d);
        self.class_lat[self.ops[slot as usize].class as usize].record(d);
        if self.ctrl.is_some() {
            self.timeline.push((now.ps(), d));
        }
        self.ops[slot as usize].active = false;
        self.completed += 1;
        self.free.push(slot);
        if !self.cfg.cached {
            self.release(addr);
        }
    }

    /// Streaming-client release: voluntarily downgrade the line back to
    /// `I` so the next touch reaches the directory again.
    fn release(&mut self, addr: LineAddr) {
        let fx = self.remote.evict(addr, &mut self.cache);
        let mut sent = false;
        for e in fx {
            match e {
                RemoteEffect::Send(m) => {
                    self.offer_home(m);
                    sent = true;
                }
                // mid-transaction (another op owns the line): keep it
                RemoteEffect::Stalled => self.counters.inc("release_deferred"),
                RemoteEffect::Filled { .. } => {}
                RemoteEffect::ForeignVictim(_) => self.counters.inc("foreign_victim"),
            }
        }
        if sent {
            self.counters.inc("released");
            self.pump_home();
        }
    }

    fn wake(&mut self, addr: LineAddr) {
        let Some(slots) = self.waiters.remove(&addr) else { return };
        for s in slots {
            self.eng.schedule(Duration::ZERO, Ev::Step(s));
        }
    }

    // -- link pumping -------------------------------------------------------

    fn pump_home(&mut self) {
        let now = self.eng.now();
        // requests piggyback the cumulative acks this node (the cpu)
        // owes for the responses it received — stolen only when a frame
        // will actually launch (else the delayed flush handles it)
        self.to_home.steal_piggy_from(&mut self.to_cpu);
        let mut out = std::mem::take(&mut self.scratch);
        self.to_home.pump(now, &mut out);
        for (at, f) in out.drain(..) {
            if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                // repeat launches of a tracked id are retransmit episodes
                sp.mark(now, f.msg.id.0, Stage::Launch);
            }
            self.eng.schedule_at(at, Ev::LandHome(Box::new(f)));
        }
        self.scratch = out;
        self.peak_in_flight = self.peak_in_flight.max(self.to_home.in_flight_total());
        self.arm_retx(0);
    }

    fn pump_cpu(&mut self) {
        let now = self.eng.now();
        // responses piggyback the acks the home owes for received
        // requests — stolen only when a frame will actually launch
        self.to_cpu.steal_piggy_from(&mut self.to_home);
        let mut out = std::mem::take(&mut self.scratch);
        self.to_cpu.pump(now, &mut out);
        for (at, f) in out.drain(..) {
            self.eng.schedule_at(at, Ev::LandCpu(Box::new(f)));
        }
        self.scratch = out;
        self.arm_retx(1);
    }

    // -- home side ----------------------------------------------------------

    fn land_home(&mut self, frame: Box<Frame>) {
        let now = self.eng.now();
        let ctrl = self.cfg.machine.ctrl_latency;
        // a piggybacked ack acknowledges response frames this node (the
        // home) sent toward the cpu
        if let Some((vc, seq)) = frame.ack {
            self.to_cpu.on_control(now, Control::VcAck(vc, seq));
        }
        // a selective-repeat delivery can release several frames (a
        // hole fill frees its buffered successors), all in per-VC order
        let mut delivered = std::mem::take(&mut self.rx_frames);
        let mut ctls = std::mem::take(&mut self.rx_ctls);
        self.to_home.deliver(*frame, &mut delivered, &mut ctls);
        for c in ctls.drain(..) {
            self.eng.schedule(ctrl, Ev::CtlHome(c));
        }
        self.rx_ctls = ctls;
        self.arm_ack_flush(0);
        for f in delivered.drain(..) {
            if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                sp.mark(now, f.msg.id.0, Stage::Deliver);
            }
            let s = self.dcs.enqueue_frame(now, f);
            self.pump_slice(s);
        }
        self.rx_frames = delivered;
    }

    /// Drain slice `s` as far as its pipeline allows right now. Credits
    /// flow back to the generator as the slice consumes messages — that
    /// is the backpressure loop.
    fn pump_slice(&mut self, s: usize) {
        if s >= self.dcs.slices() {
            // stale poll scheduled against a pre-reconfiguration shape
            // (the slice was resliced away mid-quiesce; its queues were
            // provably empty at the handoff)
            return;
        }
        let now = self.eng.now();
        let ctrl = self.cfg.machine.ctrl_latency;
        loop {
            match self.dcs.service_one(s, now, &mut self.mem) {
                None => break,
                Some(SliceService::Busy(t)) => {
                    // one outstanding poll per slice is enough
                    if self.poll_at[s] < t {
                        self.poll_at[s] = t;
                        self.eng.schedule_at(t, Ev::Poll(s as u32));
                    }
                    break;
                }
                Some(SliceService::Done(ready, vc, _, fx)) => {
                    self.eng.schedule_at(ready + ctrl, Ev::CreditHome(vc));
                    self.handle_effects(ready, fx);
                }
            }
        }
    }

    fn handle_effects(&mut self, ready: Time, fx: Vec<HomeEffect>) {
        for e in fx {
            match e {
                HomeEffect::Respond { msg, from_ram } => {
                    let t = if self.chase_ids.remove(&msg.id.0) {
                        // chase hop: pointer resolution through the KVS
                        // engine pool
                        self.counters.inc("chase_via_kvs");
                        self.kvs.submit(ready, 1, &mut self.dram)
                    } else if from_ram {
                        self.dram.read(ready, msg.addr)
                    } else {
                        ready
                    };
                    if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                        // the slice occupied the pipeline for slice_proc
                        // ending at `ready`; the backend (home cache,
                        // FPGA DRAM, or KVS pool) holds the reply until
                        // `t`
                        let proc = self.dcs.cfg.slice_proc.ps();
                        let start = Time(ready.ps().saturating_sub(proc));
                        sp.mark(start, msg.id.0, Stage::SvcStart);
                        sp.mark(ready, msg.id.0, Stage::SvcDone);
                        sp.mark(t, msg.id.0, Stage::Reply);
                    }
                    self.eng.schedule_at(t, Ev::HomeSend(Box::new(msg)));
                }
                HomeEffect::Fwd { msg } => {
                    self.eng.schedule_at(ready, Ev::HomeSend(Box::new(msg)));
                }
                HomeEffect::RamWrite { addr } => {
                    self.dram.write(ready, addr);
                }
                HomeEffect::LocalDone { .. } => {}
            }
        }
    }

    // -- cpu side -----------------------------------------------------------

    fn land_cpu(&mut self, frame: Box<Frame>) {
        let now = self.eng.now();
        let ctrl = self.cfg.machine.ctrl_latency;
        // a piggybacked ack acknowledges request frames this node (the
        // cpu) sent toward the home
        if let Some((avc, seq)) = frame.ack {
            self.to_home.on_control(now, Control::VcAck(avc, seq));
        }
        let mut delivered = std::mem::take(&mut self.rx_frames);
        let mut ctls = std::mem::take(&mut self.rx_ctls);
        self.to_cpu.deliver(*frame, &mut delivered, &mut ctls);
        for c in ctls.drain(..) {
            self.eng.schedule(ctrl, Ev::CtlCpu(c));
        }
        self.rx_ctls = ctls;
        self.arm_ack_flush(1);
        let mut sent = false;
        let mut fills: Vec<LineAddr> = Vec::new();
        for f in delivered.drain(..) {
            // the cpu sinks responses at arrival: slot freed immediately
            self.eng.schedule(ctrl, Ev::CreditCpu(f.vc));
            if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                if matches!(f.msg.kind, MsgKind::CohRsp { .. }) {
                    sp.complete(now, f.msg.id.0);
                }
            }
            let fx = self.remote.on_message(f.msg, &mut self.cache);
            for e in fx {
                match e {
                    RemoteEffect::Send(m) => {
                        self.offer_home(m);
                        sent = true;
                    }
                    RemoteEffect::Filled { addr } => fills.push(addr),
                    RemoteEffect::Stalled => {}
                    RemoteEffect::ForeignVictim(_) => self.counters.inc("foreign_victim"),
                }
            }
        }
        self.rx_frames = delivered;
        if sent {
            self.pump_home();
        }
        for a in fills {
            self.wake(a);
        }
    }

    // -- control plane ------------------------------------------------------

    /// A scripted reconfiguration event fires: begin quiescing (or
    /// defer behind the transition already in flight, or record a
    /// post-completion event as skipped).
    fn ctrl_begin(&mut self, i: usize) {
        let now = self.eng.now();
        let done = self.completed >= self.cfg.ops;
        let Some(c) = self.ctrl.as_deref_mut() else { return };
        let ev = c.events[i];
        if done {
            // fired after the run's completion target (e.g. during
            // settle): record it, change nothing
            let ord = c.records.len() as u64;
            c.records.push(TransitionRecord::skipped_at(ev, now));
            if let Some(o) = self.obs.as_mut() {
                o.flight_record(now, 0, FlightKind::ReconfigSkipped, ord, 0);
            }
            return;
        }
        if c.quiescing() {
            // one transition at a time; this one begins at the
            // in-flight one's resume
            c.backlog.push_back(i);
            return;
        }
        c.phase = Phase::Quiescing;
        c.active = Some(i);
        let ord = c.records.len() as u64;
        c.records.push(TransitionRecord::begun(ev, now));
        if let Some(o) = self.obs.as_mut() {
            o.flight_record(now, 0, FlightKind::ReconfigQuiesce, ord, 0);
        }
        self.eng.schedule(Duration::ZERO, Ev::QuiesceCheck);
    }

    /// The quiesce predicate: nothing issued is unfinished, nothing is
    /// queued, staged, or in flight on either link direction, and no
    /// reliable-link frame awaits acknowledgement. With arrivals
    /// parked, this is monotone — once true it stays true until the
    /// handoff resumes traffic.
    fn data_plane_quiet(&self) -> bool {
        self.completed == self.issued
            && self.waiters.is_empty()
            && self.dcs.pending() == 0
            && self.to_home.queued() == 0
            && self.to_cpu.queued() == 0
            && self.to_home.in_flight_total() == 0
            && self.to_cpu.in_flight_total() == 0
            && self.to_home.rel_unacked() == 0
            && self.to_cpu.rel_unacked() == 0
    }

    /// Control-plane poll: re-arm every `ctrl_latency` until the data
    /// plane is quiet, then hand off.
    fn ctrl_check(&mut self) {
        if !self.ctrl.as_ref().is_some_and(|c| c.quiescing()) {
            return;
        }
        if !self.data_plane_quiet() {
            let lat = self.cfg.machine.ctrl_latency.max(Duration::from_ns(1));
            self.eng.schedule(lat, Ev::QuiesceCheck);
            return;
        }
        self.ctrl_handoff();
    }

    /// The data plane is quiet: mutate the canonical shape and apply
    /// it — rebuild the directory (re-slice, cache resize, drain,
    /// rejoin) or swap the link reliability mode in place — then
    /// resume.
    fn ctrl_handoff(&mut self) {
        let now = self.eng.now();
        let mut c = self.ctrl.take().expect("handoff without a controller");
        let i = c.active.expect("handoff without an active transition");
        let kind = c.events[i].kind;
        c.apply(kind);
        let (moved, victims) = match kind {
            ReconfigKind::RelSwap(m) => {
                // in-place swap on both directions; a recorded no-op on
                // an unreliable link
                let a = self.to_home.set_rel_mode(m);
                let b = self.to_cpu.set_rel_mode(m);
                self.counters.inc(if a || b { "ctrl_relmode_swaps" } else { "ctrl_relmode_noop" });
                (0, 0)
            }
            _ => {
                let dcfg = c.spec.dcs_config();
                let (moved, victims, absorbed) = self.rebuild_dcs(dcfg);
                c.absorb(&absorbed);
                (moved, victims)
            }
        };
        let ord = (c.records.len() - 1) as u64;
        let rec = c.records.last_mut().expect("record pushed at begin");
        rec.handoff_at = now;
        rec.moved_lines = moved;
        rec.cache_victims = victims;
        if let Some(o) = self.obs.as_mut() {
            o.flight_record(now, 0, FlightKind::ReconfigHandoff, ord, moved);
        }
        self.ctrl = Some(c);
        self.ctrl_resume();
    }

    /// Replace the directory with one built to `dcfg`, handing every
    /// tracked line across state-exactly (residency included). Only
    /// legal quiesced. Returns `(lines moved, cache victims, retired
    /// instance's counters)`.
    fn rebuild_dcs(&mut self, dcfg: DcsConfig) -> (u64, u64, Counters) {
        debug_assert_eq!(self.dcs.pending(), 0, "rebuild on a non-quiet directory");
        let absorbed = self.dcs.counters();
        let mut next = Dcs::with_reference_rules(dcfg);
        let mut moved = 0u64;
        let mut victims = 0u64;
        for i in 0..self.region_lines {
            let addr = LineAddr(i);
            if let Some(ex) = self.dcs.export_line(addr) {
                moved += 1;
                victims += next.import_line(addr, ex, &mut self.mem);
            }
        }
        debug_assert_eq!(
            self.dcs.tracked_lines(),
            0,
            "lines left behind in the retired directory"
        );
        self.dcs = next;
        // dedup state for the new shape; stale polls against the old
        // one are bounds-guarded in pump_slice
        self.poll_at = vec![Time::ZERO; self.dcs.slices()];
        if let Some(o) = self.obs.as_mut() {
            // per-slice gauge names change cardinality with the shape:
            // retire the old registrations so the next refresh
            // re-registers cleanly within its epoch
            o.registry.retire_prefix("dcs.");
        }
        (moved, victims, absorbed)
    }

    /// Release parked arrivals FIFO with their original timestamps,
    /// then start the next backlogged transition, if any.
    fn ctrl_resume(&mut self) {
        let now = self.eng.now();
        let mut c = self.ctrl.take().expect("resume without a controller");
        let released = self.parked.len() as u64;
        let ord = (c.records.len() - 1) as u64;
        {
            let rec = c.records.last_mut().expect("record pushed at begin");
            rec.resume_at = now;
            rec.parked = released;
        }
        c.phase = Phase::Idle;
        c.active = None;
        if let Some(o) = self.obs.as_mut() {
            o.flight_record(now, 0, FlightKind::ReconfigResume, ord, released);
        }
        self.ctrl = Some(c);
        while let Some(started) = self.parked.pop_front() {
            self.spawn_at(started);
        }
        let next = self.ctrl.as_deref_mut().and_then(|c| c.backlog.pop_front());
        if let Some(i) = next {
            self.ctrl_begin(i);
        }
    }
}

/// Convenience: run `scenario` at the configured offered rate against a
/// fresh `slices`-slice directory.
pub fn run(cfg: OpenLoopConfig, scenario: &Scenario, slices: usize) -> OpenLoopReport {
    OpenLoop::new(cfg, scenario, slices).run()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_named(name: &str, rate: f64, ops: u64, slices: usize) -> OpenLoopReport {
        let cfg = OpenLoopConfig { rate_per_s: rate, ops, ..Default::default() };
        let sc = Scenario::preset(name, 1 << 12, 0.99).expect("preset");
        run(cfg, &sc, slices)
    }

    #[test]
    fn completes_every_arrival_and_measures() {
        let r = run_named("uniform", 4e6, 1_500, 2);
        assert_eq!(r.completed, 1_500);
        assert_eq!(r.lat.count(), 1_500);
        assert!(r.delivered_per_s > 0.0);
        assert!(r.sim_time > Time(0));
        assert!(r.p99_ns() >= r.p50_ns());
        assert!(r.p999_ns() >= r.p99_ns());
        assert_eq!(r.per_slice_served.len(), 2);
        assert!(r.per_slice_served.iter().all(|&s| s > 0), "{:?}", r.per_slice_served);
        assert!(r.served_skew >= 1.0);
        // the streaming client must actually release lines
        assert!(r.counters.get("released") > 0, "{:?}", r.counters);
        // and chases must resolve through the KVS pool
        assert!(r.counters.get("chase_via_kvs") > 0, "{:?}", r.counters);
    }

    #[test]
    fn overload_manifests_as_credit_exhaustion_and_queue_growth() {
        let low = run_named("scan", 2e6, 1_200, 1);
        let high = run_named("scan", 100e6, 1_200, 1);
        assert_eq!(high.completed, 1_200, "open loop must still drain");
        assert!(
            high.credit_stalls > low.credit_stalls,
            "overload must exhaust credits: {} vs {}",
            high.credit_stalls,
            low.credit_stalls
        );
        assert!(high.credit_stalls > 0);
        assert!(
            high.peak_tx_queue > 200,
            "overload must grow the transmit queue, peak {}",
            high.peak_tx_queue
        );
        assert!(
            high.p99_ns() > 5.0 * low.p99_ns(),
            "overload must blow up tail latency: {} vs {}",
            high.p99_ns(),
            low.p99_ns()
        );
        // delivered throughput saturates well below the offered rate
        assert!(high.delivered_per_s < 0.7 * high.offered_per_s);
        assert!(low.delivered_per_s > 0.8 * low.offered_per_s);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = run_named("tenants", 8e6, 1_000, 2);
        let b = run_named("tenants", 8e6, 1_000, 2);
        assert_eq!(a.sim_time, b.sim_time);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.per_slice_served, b.per_slice_served);
        assert_eq!(a.lat.count(), b.lat.count());
    }

    #[test]
    fn caching_client_absorbs_hot_lines() {
        let sc = Scenario::preset("hot-kvs", 1 << 12, 0.99).expect("preset");
        let mk = |cached| {
            let cfg =
                OpenLoopConfig { rate_per_s: 3e6, ops: 1_200, cached, ..Default::default() };
            run(cfg, &sc, 2)
        };
        let streaming = mk(false);
        let cached = mk(true);
        assert_eq!(streaming.completed, 1_200);
        assert_eq!(cached.completed, 1_200);
        // a caching client satisfies repeat touches locally, so far
        // fewer operations reach the directory
        let served = |r: &OpenLoopReport| r.per_slice_served.iter().sum::<u64>();
        assert!(
            served(&cached) < served(&streaming),
            "cached {} vs streaming {}",
            served(&cached),
            served(&streaming)
        );
        assert_eq!(cached.counters.get("released"), 0);
    }

    #[test]
    fn home_cached_slices_cut_latency_on_hot_kvs() {
        // streaming clients release every line, so every repeat read
        // reaches the directory — exactly where a slice-local home cache
        // replaces the FPGA-DRAM round trip
        let sc = Scenario::preset("hot-kvs", 1 << 12, 0.99).expect("preset");
        let mk = |home_cached| {
            let cfg = OpenLoopConfig {
                rate_per_s: 3e6,
                ops: 2_000,
                home_cached,
                ..Default::default()
            };
            run(cfg, &sc, 2)
        };
        let plain = mk(false);
        let cached = mk(true);
        assert_eq!(plain.completed, 2_000);
        assert_eq!(cached.completed, 2_000);
        assert_eq!(plain.counters.get("home_cache_hit"), 0);
        assert!(cached.counters.get("home_cache_hit") > 0, "{:?}", cached.counters);
        assert!(
            cached.p50_ns() < plain.p50_ns(),
            "cached slices p50 {} must beat cache-less {}",
            cached.p50_ns(),
            plain.p50_ns()
        );
    }

    #[test]
    fn ingress_batching_is_credit_bounded_and_drains() {
        // overload with batching on: staged frames keep their credits,
        // so in-flight never exceeds the budget, and the open loop still
        // completes every arrival
        let mk = |batch: usize| {
            let mut cfg = OpenLoopConfig { rate_per_s: 60e6, ops: 1_500, ..Default::default() };
            cfg.machine.ingress_batch = batch;
            let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
            run(cfg, &sc, 1)
        };
        let plain = mk(1);
        let batched = mk(4);
        assert_eq!(plain.completed, 1_500);
        assert_eq!(batched.completed, 1_500, "batched overload must still drain");
        let budget =
            OpenLoopConfig::default().machine.link.credits_per_vc * crate::transport::NUM_VCS as u32;
        assert!(batched.peak_in_flight > 0);
        assert!(
            batched.peak_in_flight <= budget,
            "batched in-flight {} exceeds credit budget {budget}",
            batched.peak_in_flight
        );
        assert!(plain.peak_in_flight <= budget);
        // batching actually formed multi-frame deliveries under overload
        assert!(batched.counters.get("ingress_deliveries") > 0);
        assert!(
            batched.counters.get("ingress_batched_frames")
                > batched.counters.get("ingress_deliveries"),
            "overload must produce batches larger than one: {:?}",
            batched.counters
        );
        assert_eq!(plain.counters.get("ingress_deliveries"), 0);
    }

    #[test]
    fn per_class_latency_breakdown_covers_every_completion() {
        let cfg = OpenLoopConfig { rate_per_s: 4e6, ops: 1_200, ..Default::default() };
        let sc = Scenario::preset("tenants", 1 << 12, 0.99).expect("preset");
        let r = run(cfg, &sc, 2);
        assert_eq!(r.per_class.len(), 3, "one breakdown entry per tenant class");
        assert_eq!(r.per_class.iter().map(|c| c.completed).sum::<u64>(), 1_200);
        for c in &r.per_class {
            assert!(c.completed > 0, "every class must complete ops: {:?}", r.per_class);
            assert!(c.p999_ns() >= c.p99_ns() && c.p99_ns() >= c.p50_ns(), "{}", c.class);
        }
        assert_eq!(r.per_class[0].class, "hot-kvs");
        // dependent 4-hop chases must sit far above single-access reads
        let chase = r.per_class.iter().find(|c| c.class == "chase").unwrap();
        let scan = r.per_class.iter().find(|c| c.class == "scan").unwrap();
        assert!(
            chase.p50_ns() > 2.0 * scan.p50_ns(),
            "chase p50 {} should dwarf scan p50 {}",
            chase.p50_ns(),
            scan.p50_ns()
        );
        assert_eq!(r.frame_goodput, 1.0, "a clean link wastes no frames");
    }

    #[test]
    fn lossy_link_completes_everything_and_reports_replay() {
        use crate::transport::rel::{FaultConfig, FaultSpec, RelConfig};
        let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
        let mut cfg = OpenLoopConfig { rate_per_s: 2e6, ops: 800, ..Default::default() };
        let spec = FaultSpec { ber: 1e-4, drop: 0.02, reorder: 0.02, burst_len: 1.0 };
        cfg.machine.rel = Some(RelConfig::new(FaultConfig::new(spec, 7)));
        let r = run(cfg, &sc, 2);
        assert_eq!(r.completed, 800, "loss must never lose an operation");
        assert!(r.frame_goodput < 1.0, "replays must cost frames: {}", r.frame_goodput);
        assert!(r.frame_goodput > 0.5, "goodput collapsed: {}", r.frame_goodput);
        assert!(r.counters.get("rel_retransmitted") > 0, "{:?}", r.counters);
        assert!(
            r.counters.get("rel_injected_drops") > 0,
            "drops must have been injected: {:?}",
            r.counters
        );
    }

    #[test]
    fn observed_run_produces_waterfall_and_telemetry() {
        let cfg = OpenLoopConfig { rate_per_s: 4e6, ops: 1_000, ..Default::default() };
        let sc = Scenario::preset("uniform", 1 << 12, 0.99).expect("preset");
        let ocfg = ObsConfig {
            spans: true,
            span_sample_every: 4,
            tick: Some(Duration::from_us(5)),
            ..ObsConfig::default()
        };
        let (r, obs) = OpenLoop::new(cfg, &sc, 2).with_obs(&ocfg).run_observed();
        assert_eq!(r.completed, 1_000);
        let w = obs.waterfall.expect("spans were on");
        assert!(w.sampled > 0);
        assert!(w.completed > 0, "sampled spans must complete: {w:?}");
        assert_eq!(w.rows.len(), 6);
        assert!(w.rows.iter().all(|row| row.count == w.completed));
        // stage means telescope to the span end-to-end mean
        let sum = w.stage_mean_sum_ns();
        assert!(
            (sum - w.e2e.mean_ns).abs() <= 1e-6 * w.e2e.mean_ns.max(1.0),
            "stage sum {sum} vs e2e {}",
            w.e2e.mean_ns
        );
        // home service is pinned at slice_proc by construction
        let svc = &w.rows[3];
        let proc_ns = OpenLoopConfig::default().machine.home_proc.as_ns();
        assert!(
            (svc.mean_ns - proc_ns).abs() < 1e-6,
            "home_service mean {} vs slice_proc {proc_ns}",
            svc.mean_ns
        );
        // telemetry ran and the registry absorbed all three surfaces
        assert!(!obs.jsonl.is_empty());
        assert_eq!(obs.registry.get("workload.completed"), 1_000);
        assert!(obs.registry.get("dcs.slices_served") > 0);
        assert!(obs.registry.get("ingress.to_home.offered") > 0);
    }

    #[test]
    fn live_reslice_is_transparent_to_the_settled_state() {
        // read-only scan: the settled digest is time-independent, so a
        // mid-run 2->4 reslice must land on exactly the baseline digest
        let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
        let cfg = OpenLoopConfig { rate_per_s: 4e6, ops: 2_000, ..Default::default() };
        let (base, base_digest) = OpenLoop::new(cfg, &sc, 2).run_settled();
        let evs = vec![ReconfigEvent::parse("reslice:4@50us").unwrap()];
        let (r, digest) = OpenLoop::new(cfg, &sc, 2).with_reconfig(evs).run_settled();
        assert_eq!(r.completed, 2_000, "every arrival completes across the transition");
        assert_eq!(base.completed, 2_000);
        assert_eq!(digest, base_digest, "reconfigured run must settle identically");
        let rc = r.reconfig.expect("ctrl was attached");
        assert_eq!(rc.executed(), 1);
        let t = &rc.transitions[0];
        assert!(matches!(t.kind, crate::ctrl::ReconfigKind::Reslice(4)));
        assert!(!t.skipped);
        assert!(t.handoff_at >= t.quiesce_start);
        assert!(t.resume_at >= t.handoff_at);
        assert_eq!(rc.timeline.len(), 2_000, "one timeline point per completion");
        assert_eq!(r.per_slice_served.len(), 4, "the final shape has four slices");
        assert!(base.reconfig.is_none(), "no ctrl, no reconfig report");
    }

    #[test]
    fn quiesce_parks_arrivals_and_the_stall_shows_in_latency() {
        let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
        let cfg = OpenLoopConfig {
            rate_per_s: 8e6,
            ops: 2_000,
            home_cached: true,
            ..Default::default()
        };
        let evs = vec![ReconfigEvent::parse("cache:0@100us").unwrap()];
        let r = OpenLoop::new(cfg, &sc, 2).with_reconfig(evs).run();
        assert_eq!(r.completed, 2_000);
        let rc = r.reconfig.expect("ctrl was attached");
        let t = &rc.transitions[0];
        assert!(t.parked > 0, "a sustained arrival process must park ops mid-quiesce");
        assert!(t.stall_us() >= t.quiesce_us());
        // turning the home cache off evicts every resident line through
        // the writeback path
        assert!(t.moved_lines > 0, "cached-directory lines must hand off");
        assert!(t.cache_victims > 0, "cache:0 must evict residents: {t:?}");
        // counter continuity: hits recorded before the resize survive
        // in the final report
        assert!(r.counters.get("home_cache_hit") > 0, "{:?}", r.counters);
    }

    #[test]
    fn relmode_swap_midrun_stays_lossless_under_faults() {
        use crate::transport::rel::{FaultConfig, FaultSpec, RelConfig};
        let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
        let spec = FaultSpec { ber: 1e-5, drop: 0.01, reorder: 0.0, burst_len: 1.0 };
        let mut cfg = OpenLoopConfig { rate_per_s: 2e6, ops: 1_000, ..Default::default() };
        cfg.machine.rel = Some(RelConfig::new(FaultConfig::new(spec, 11)));
        let (_, base_digest) = OpenLoop::new(cfg, &sc, 2).run_settled();
        let evs = vec![ReconfigEvent::parse("relmode:sr@100us").unwrap()];
        let (r, digest) = OpenLoop::new(cfg, &sc, 2).with_reconfig(evs).run_settled();
        assert_eq!(r.completed, 1_000);
        assert_eq!(digest, base_digest, "rel-mode swap must not change what, only when");
        assert_eq!(r.counters.get("ctrl_relmode_swaps"), 1, "{:?}", r.counters);
        assert!(r.counters.get("rel_retransmitted") > 0, "faults were live: {:?}", r.counters);
    }

    #[test]
    fn post_completion_reconfig_event_is_recorded_as_skipped() {
        let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
        let cfg = OpenLoopConfig { rate_per_s: 4e6, ops: 400, ..Default::default() };
        // ~100us of traffic; the event fires at 1s, deep in settle
        let evs = vec![ReconfigEvent::parse("reslice:4@1000000us").unwrap()];
        let (r, _) = OpenLoop::new(cfg, &sc, 2).with_reconfig(evs).run_settled();
        assert_eq!(r.completed, 400);
        let rc = r.reconfig.expect("ctrl was attached");
        assert_eq!(rc.executed(), 0);
        assert_eq!(rc.transitions.len(), 1);
        assert!(rc.transitions[0].skipped);
        assert_eq!(r.per_slice_served.len(), 2, "the shape never changed");
    }

    #[test]
    fn deterministic_arrivals_also_run() {
        let cfg = OpenLoopConfig {
            rate_per_s: 5e6,
            ops: 600,
            arrivals: ArrivalKind::Deterministic,
            ..Default::default()
        };
        let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
        let r = run(cfg, &sc, 1);
        assert_eq!(r.completed, 600);
    }
}
