//! Zipf(θ) line-popularity sampler.
//!
//! Skewed popularity is what turns "N directory slices" into a
//! load-balancing question: under a uniform draw every slice sees
//! `1/N` of the traffic, but real key-value and object workloads follow
//! a power law (YCSB's default is Zipf θ≈0.99), so a handful of hot
//! lines — wherever the address interleave happens to place them —
//! dominate one slice's ingress while its siblings idle.
//!
//! The sampler is exact inversion over a precomputed CDF table:
//! `P(rank = k) ∝ 1/(k+1)^θ`, one `f64` per rank, binary-searched per
//! draw. Footprints in this repo top out around 2^16–2^20 lines, where
//! the table is small, construction is a one-time O(n) pass, and —
//! unlike rejection samplers — the empirical distribution matches the
//! analytic CDF by construction (pinned, with determinism, by property
//! tests in `rust/tests/props.rs`). θ = 0 degenerates to uniform.

use crate::sim::rng::Rng;

/// Exact Zipf(θ) sampler over ranks `0..n` (rank 0 is the hottest).
#[derive(Clone, Debug)]
pub struct Zipf {
    /// `cdf[k]` = P(rank <= k); monotone, `cdf[n-1]` == 1.0.
    cdf: Vec<f64>,
    theta: f64,
}

impl Zipf {
    pub fn new(n: u64, theta: f64) -> Zipf {
        assert!(n > 0, "Zipf needs a non-empty support");
        assert!(theta >= 0.0 && theta.is_finite(), "bad Zipf theta {theta}");
        let n = usize::try_from(n).expect("Zipf support too large for a CDF table");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(theta);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        // guard against the last entry rounding below 1.0
        if let Some(last) = cdf.last_mut() {
            *last = 1.0;
        }
        Zipf { cdf, theta }
    }

    pub fn n(&self) -> u64 {
        self.cdf.len() as u64
    }

    pub fn theta(&self) -> f64 {
        self.theta
    }

    /// Analytic CDF: P(rank <= k).
    pub fn cdf(&self, k: u64) -> f64 {
        self.cdf[k as usize]
    }

    /// Probability mass of one rank.
    pub fn pmf(&self, k: u64) -> f64 {
        let k = k as usize;
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }

    /// Draw one rank by CDF inversion.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.f64();
        // smallest k with cdf[k] > u (u < 1.0, cdf[n-1] == 1.0)
        let k = self.cdf.partition_point(|&c| c <= u);
        k.min(self.cdf.len() - 1) as u64
    }

    /// A sampler plus its rank scatter: a seeded permutation mapping
    /// rank -> line offset, so the hot set lands on arbitrary directory
    /// slices instead of rank 0 always hitting slice 0. Shared by the
    /// closed-loop (`dcs::loadgen`) and open-loop (`workload::openloop`)
    /// generators so both place hot lines the same way.
    pub fn scattered(n: u64, theta: f64, rng: &mut Rng) -> (Zipf, Vec<u32>) {
        assert!(n <= u32::MAX as u64, "Zipf support too large to scatter");
        let mut perm: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut perm);
        (Zipf::new(n, theta), perm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_is_monotone_and_normalized() {
        let z = Zipf::new(1000, 0.99);
        let mut prev = 0.0;
        for k in 0..1000 {
            let c = z.cdf(k);
            assert!(c >= prev, "CDF not monotone at {k}");
            prev = c;
        }
        assert_eq!(z.cdf(999), 1.0);
        assert!((0..1000).map(|k| z.pmf(k)).sum::<f64>() > 0.999_999);
    }

    #[test]
    fn rank_zero_dominates_under_skew() {
        let z = Zipf::new(4096, 0.99);
        // H_4096(0.99) ≈ 9.3, so the hottest line holds ~11% of the mass
        assert!(z.pmf(0) > 0.08 && z.pmf(0) < 0.15, "pmf(0) = {}", z.pmf(0));
        assert!(z.pmf(0) > 100.0 * z.pmf(4095));
    }

    #[test]
    fn theta_zero_is_uniform() {
        let z = Zipf::new(64, 0.0);
        for k in 0..64 {
            assert!((z.pmf(k) - 1.0 / 64.0).abs() < 1e-12);
        }
    }

    #[test]
    fn samples_stay_in_range_and_skew_low() {
        let z = Zipf::new(128, 1.2);
        let mut rng = Rng::new(0x21BF);
        let mut hits0 = 0u32;
        for _ in 0..10_000 {
            let k = z.sample(&mut rng);
            assert!(k < 128);
            if k == 0 {
                hits0 += 1;
            }
        }
        // pmf(0) ≈ 0.28 at θ=1.2, n=128; 10k draws cannot miss by much
        assert!(hits0 > 1_500, "rank 0 drawn only {hits0} times");
    }

    #[test]
    fn single_rank_support() {
        let z = Zipf::new(1, 0.99);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut rng), 0);
        }
    }
}
