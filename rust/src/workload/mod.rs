//! workload — open-loop, scenario-driven traffic generation.
//!
//! The dcs gave the reproduction a *finite-throughput* directory; this
//! subsystem gives it *offered load*. Three pieces compose (DESIGN.md
//! §"The workload subsystem"):
//!
//! * **Arrival processes** ([`arrival`]) — operations arrive on their
//!   own deterministic or Poisson clock at a configured rate, instead
//!   of being issued one-per-client-completion. Only an open loop can
//!   drive the directory *past* saturation, which is where the
//!   latency-vs-load hockey stick of `harness::fig_loadcurve` lives.
//! * **Scenarios** ([`scenario`], [`zipf`]) — traffic is described as a
//!   composition of tenant-like classes (per-class op mix, footprint,
//!   rate share, and line popularity — uniform or Zipf(θ) with a seeded
//!   rank scatter), so hot-spot skew across directory slices becomes a
//!   first-class experimental knob rather than a property baked into
//!   one generator loop.
//! * **Credit-accurate admission** ([`openloop`]) — generated traffic
//!   enters through the real transport stack
//!   ([`crate::transport::FramedIngress`]: VC arbitration, per-VC
//!   credits, frame sequencing, serial-lane occupancy) and the
//!   request-direction credit is held until the owning directory slice
//!   consumes the message, so overload manifests as credit exhaustion
//!   and transmit-queue growth — not as an unbounded pile of in-flight
//!   messages the model silently absorbs.
//!
//! The sweep harness is `harness::fig_loadcurve` (knee detection per
//! slice count); the CLI entry is `eci bench workload`.

pub mod arrival;
pub mod openloop;
pub mod sampler;
pub mod scenario;
pub mod zipf;

pub use arrival::{ArrivalKind, Arrivals};
pub use openloop::{run, ClassLatency, OpenLoop, OpenLoopConfig, OpenLoopReport};
pub use sampler::{SampleKind, TrafficSampler};
pub use scenario::{Popularity, Scenario, TrafficClass};
pub use zipf::Zipf;
