//! Traffic classes and named scenarios.
//!
//! A *scenario* replaces the single fixed client loop with a composition
//! of tenant-like traffic classes, each with its own operation mix,
//! footprint, line-popularity model, and share of the offered arrival
//! rate. The open-loop engine ([`super::openloop`]) draws every arrival
//! by (class, op kind, line) from this description, so "what traffic
//! hits the directory" becomes data, not code.
//!
//! Presets mirror the workloads coherent-accelerator evaluations sweep:
//!
//! | name      | mix (r:w:c)  | popularity | footprint  | stresses        |
//! |-----------|--------------|------------|------------|-----------------|
//! | `uniform` | 60:20:20     | uniform    | 1×         | baseline mix    |
//! | `hot-kvs` | 70:10:20     | Zipf(θ)    | 1/4×       | one hot slice   |
//! | `scan`    | 100:0:0      | uniform    | 1×         | ingress bandwidth |
//! | `chase`   | 0:0:100 (4h) | uniform    | 1/2×       | KVS engine pool |
//! | `tenants` | all three    | mixed      | 1.75×      | multi-tenant interference |

use crate::dcs::loadgen::MixConfig;

/// Line-popularity model of one class.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Popularity {
    /// Every line in the footprint equally likely.
    Uniform,
    /// Zipf-distributed rank popularity; ranks are scattered over the
    /// footprint by a seeded permutation so the hot set lands on
    /// arbitrary slices (hot-spot stress, not an artifact of rank 0
    /// mapping to slice 0).
    Zipf { theta: f64 },
}

/// One tenant-like traffic class.
#[derive(Clone, Debug)]
pub struct TrafficClass {
    pub name: String,
    /// Relative share of the offered arrival rate (weights need not sum
    /// to anything in particular).
    pub rate_weight: u32,
    pub mix: MixConfig,
    /// Lines this class touches; classes occupy disjoint address
    /// windows laid out back to back.
    pub footprint_lines: u64,
    pub popularity: Popularity,
}

impl TrafficClass {
    /// Skewed, read-mostly key-value traffic with short chases.
    pub fn hot_kvs(footprint_lines: u64, theta: f64) -> TrafficClass {
        TrafficClass {
            name: "hot-kvs".into(),
            rate_weight: 1,
            mix: MixConfig { reads: 70, writes: 10, chases: 20, chase_hops: 2 },
            footprint_lines,
            popularity: Popularity::Zipf { theta },
        }
    }

    /// Read-only streaming over a large region.
    pub fn scan(footprint_lines: u64) -> TrafficClass {
        TrafficClass {
            name: "scan".into(),
            rate_weight: 1,
            mix: MixConfig::read_only(),
            footprint_lines,
            popularity: Popularity::Uniform,
        }
    }

    /// Pure dependent pointer chases (Fig. 6-style traffic).
    pub fn chase(footprint_lines: u64) -> TrafficClass {
        TrafficClass {
            name: "chase".into(),
            rate_weight: 1,
            mix: MixConfig { reads: 0, writes: 0, chases: 100, chase_hops: 4 },
            footprint_lines,
            popularity: Popularity::Uniform,
        }
    }

    /// The closed-loop generator's default mix, uniform popularity.
    pub fn uniform(footprint_lines: u64) -> TrafficClass {
        TrafficClass {
            name: "uniform".into(),
            rate_weight: 1,
            mix: MixConfig::default(),
            footprint_lines,
            popularity: Popularity::Uniform,
        }
    }

    /// Look up a class preset by CLI name. `base_lines` scales the
    /// footprint; `theta` parameterizes the skewed presets.
    pub fn by_name(name: &str, base_lines: u64, theta: f64) -> Option<TrafficClass> {
        match name {
            "hot-kvs" => Some(TrafficClass::hot_kvs((base_lines / 4).max(2), theta)),
            "scan" => Some(TrafficClass::scan(base_lines.max(2))),
            "chase" => Some(TrafficClass::chase((base_lines / 2).max(2))),
            "uniform" => Some(TrafficClass::uniform(base_lines.max(2))),
            _ => None,
        }
    }

    pub fn with_weight(mut self, w: u32) -> TrafficClass {
        self.rate_weight = w;
        self
    }
}

/// A named composition of traffic classes.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub classes: Vec<TrafficClass>,
}

impl Scenario {
    pub fn new(name: impl Into<String>, classes: Vec<TrafficClass>) -> Scenario {
        assert!(!classes.is_empty(), "a scenario needs at least one class");
        for c in &classes {
            assert!(c.rate_weight > 0, "class {} has zero rate weight", c.name);
            assert!(c.footprint_lines >= 2, "class {} footprint too small", c.name);
            assert!(c.mix.total() > 0, "class {} has an empty mix", c.name);
        }
        Scenario { name: name.into(), classes }
    }

    /// Total region footprint (classes are laid out back to back).
    pub fn total_lines(&self) -> u64 {
        self.classes.iter().map(|c| c.footprint_lines).sum()
    }

    /// Sum of class rate weights.
    pub fn total_weight(&self) -> u64 {
        self.classes.iter().map(|c| c.rate_weight as u64).sum()
    }

    /// Named scenario presets; `base_lines` sizes footprints (see
    /// `harness::fig_loadcurve::footprint_for` for the scale mapping).
    pub fn preset(name: &str, base_lines: u64, theta: f64) -> Option<Scenario> {
        let s = match name {
            "uniform" | "hot-kvs" | "scan" | "chase" => Scenario::new(
                name,
                vec![TrafficClass::by_name(name, base_lines, theta).expect("preset class")],
            ),
            // the multi-tenant composition: a hot KVS tenant takes half
            // the offered rate, a scanner and a chaser share the rest
            "tenants" => Scenario::new(
                "tenants",
                vec![
                    TrafficClass::hot_kvs((base_lines / 4).max(2), theta).with_weight(2),
                    TrafficClass::scan(base_lines.max(2)),
                    TrafficClass::chase((base_lines / 2).max(2)),
                ],
            ),
            _ => return None,
        };
        Some(s)
    }

    /// The preset names, for CLI usage text.
    pub fn preset_names() -> &'static [&'static str] {
        &["uniform", "hot-kvs", "scan", "chase", "tenants"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_compose() {
        for name in Scenario::preset_names() {
            let s = Scenario::preset(name, 1 << 12, 0.99).unwrap();
            assert_eq!(&s.name, name);
            assert!(s.total_lines() >= 2);
            assert!(s.total_weight() >= 1);
        }
        assert!(Scenario::preset("nope", 1 << 12, 0.99).is_none());
    }

    #[test]
    fn tenants_is_multi_class_with_skewed_kvs() {
        let s = Scenario::preset("tenants", 1 << 12, 0.99).unwrap();
        assert_eq!(s.classes.len(), 3);
        assert!(matches!(s.classes[0].popularity, Popularity::Zipf { theta } if theta == 0.99));
        assert_eq!(s.classes[0].rate_weight, 2);
        assert_eq!(s.total_lines(), (1 << 10) + (1 << 12) + (1 << 11));
    }

    #[test]
    #[should_panic]
    fn empty_scenario_is_rejected() {
        let _ = Scenario::new("empty", vec![]);
    }

    #[test]
    #[should_panic]
    fn zero_weight_class_is_rejected() {
        let _ = Scenario::new("w0", vec![TrafficClass::scan(64).with_weight(0)]);
    }
}
