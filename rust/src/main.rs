// CLI entrypoint (built out in config/cli)
fn main() { eci::config::cli::main_entry(); }
