//! # ECI — a customizable cache-coherency stack for hybrid FPGA-CPU systems
//!
//! A full-system, execution-driven reproduction of the ECI/ACCI paper
//! (Ramdas et al., ETH Zurich, 2022) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 3 (this crate)** — the protocol itself ([`proto`]), the
//!   layered transport ([`transport`]), the coherence agents and machine
//!   models ([`agents`], [`machine`]), the sharded directory and its
//!   traffic generators ([`dcs`], [`workload`]), the smart memory
//!   controller and its operators ([`memctl`], [`operators`]), the
//!   trace/verification toolkit ([`trace`]), the runtime observability
//!   layer ([`obs`] — span tracing, telemetry, JSON export), and the
//!   experiment harness ([`harness`]).
//! * **Layer 2/1 (build-time Python)** — the operators' compute hot paths
//!   as JAX + Pallas kernels, AOT-lowered to HLO text and executed from
//!   Rust through [`runtime`] (PJRT CPU client). Python is never on the
//!   request path.
//!
//! See `rust/DESIGN.md` for the layer map, the hardware-substitution
//! argument, the experiment index, and the host-side performance notes
//! (§Perf).

pub mod agents;
pub mod anyhow;
pub mod config;
pub mod ctrl;
pub mod dcs;
pub mod fabric;
pub mod harness;
pub mod machine;
pub mod memctl;
pub mod obs;
pub mod operators;
pub mod proto;
pub mod ptest;
pub mod resource;
pub mod runtime;
pub mod rustc_hash;
pub mod sim;
pub mod trace;
pub mod transport;
pub mod workload;
