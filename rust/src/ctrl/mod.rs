//! ctrl — the runtime control plane: scripted live reconfiguration of
//! a running cell behind one quiesce → handoff → resume protocol.
//!
//! The data plane (directory slices, link framing, reliability) is
//! built for steady state; every shape change — how many slices carve
//! the address space, how much home-cache budget they share, which
//! reliability mode the link runs — historically meant a fresh run.
//! This module makes those changes *online*: a [`ReconfigEvent`] fires
//! at a scripted sim time (`--reconfig reslice:4@200us`, composable
//! like `--kill`), and the host executes it in three phases common to
//! every transition kind:
//!
//! 1. **Quiesce** — new arrivals park (the arrival *clock* keeps
//!    ticking, so the arrival process and every RNG draw match the
//!    unreconfigured run bit-for-bit); in-flight operations drain until
//!    the data plane is provably quiet: no queued or unacked frames,
//!    no pending directory work, no waiters.
//! 2. **Handoff** — the one canonical shape object (a
//!    [`SystemSpec`]) is mutated, and state moves to the new shape:
//!    re-slicing and drain/rejoin export every tracked line from the
//!    retired directory and import it into the new one
//!    (state-exact, residency included — `Dcs::export_line` /
//!    `Dcs::import_line`); a cache resize funnels no-longer-resident
//!    victims through their owning slice's writeback path; a rel-mode
//!    swap flips both directions' sender/receiver in place
//!    (sequence numbers and RTT estimators continue).
//! 3. **Resume** — parked arrivals re-enter FIFO with their *original*
//!    arrival timestamps, so the quiesce stall shows up in the latency
//!    tail exactly as it would on real hardware (the `fig_reconfig`
//!    dip), and the next scripted transition (if one fired mid-quiesce)
//!    begins.
//!
//! The gate, enforced by `tests/reconfig.rs`: transitions are
//! **lossless**. A run that re-slices, drains and rejoins, resizes, or
//! swaps reliability mid-flight settles to the *same* digest
//! (per-line directory state + backing bytes) as a run that never
//! reconfigured — with and without link faults.

use std::collections::VecDeque;

use crate::config::SystemSpec;
use crate::sim::stats::Counters;
use crate::sim::time::{Duration, Time};
use crate::transport::rel::RelMode;

/// One shape change. The operand is the *target* shape, not a delta.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReconfigKind {
    /// Re-slice the directory to this many slices: the address
    /// interleave changes, so every tracked line hands off to its new
    /// owning slice.
    Reslice(usize),
    /// Resize the machine-wide home-cache budget to this many bytes
    /// (0 turns the slice caches off). Shrinks funnel evicted dirty
    /// copies through the owning slice's writeback path.
    CacheResize(usize),
    /// Swap the link-reliability mode on both directions. Sequence
    /// numbers and RTT estimators continue across the swap; the
    /// receiver's replay-dedup state migrates.
    RelSwap(RelMode),
    /// Drain one slice: it goes dark, its address range re-homes
    /// deterministically across the survivors.
    Drain(usize),
    /// Rejoin the previously drained slice: its range hands back.
    Rejoin,
}

impl ReconfigKind {
    /// Stable spelling, matching what [`ReconfigEvent::parse`] accepts.
    pub fn label(&self) -> String {
        match self {
            ReconfigKind::Reslice(n) => format!("reslice:{n}"),
            ReconfigKind::CacheResize(b) => format!("cache:{b}"),
            ReconfigKind::RelSwap(m) => format!("relmode:{}", m.name()),
            ReconfigKind::Drain(s) => format!("drain:{s}"),
            ReconfigKind::Rejoin => "rejoin".to_string(),
        }
    }
}

/// A scripted transition: *what* changes and *when* it starts
/// quiescing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReconfigEvent {
    pub at: Duration,
    pub kind: ReconfigKind,
}

/// Parse a byte count with an optional binary suffix (`64k`, `1m`).
fn parse_bytes(s: &str) -> Result<usize, String> {
    let (digits, mul) = match s.as_bytes().last() {
        Some(b'k') | Some(b'K') => (&s[..s.len() - 1], 1024),
        Some(b'm') | Some(b'M') => (&s[..s.len() - 1], 1024 * 1024),
        _ => (s, 1),
    };
    let n: usize =
        digits.parse().map_err(|_| format!("bad byte count `{s}` (want N, Nk, or Nm)"))?;
    Ok(n * mul)
}

impl ReconfigEvent {
    /// Parse a CLI spec: `<kind>[:<arg>]@<time>us`.
    ///
    /// Kinds: `reslice:<n>`, `cache:<bytes>[k|m]`, `relmode:<gbn|sr>`
    /// (alias `rel:`), `drain:<slice>`, `rejoin`. The time is
    /// microseconds of sim time, with an optional `us` suffix —
    /// `reslice:4@200us`, `rejoin@350`.
    pub fn parse(s: &str) -> Result<ReconfigEvent, String> {
        let (lhs, rhs) = s
            .split_once('@')
            .ok_or_else(|| format!("reconfig spec `{s}` needs `@<time>us`"))?;
        let digits = rhs.strip_suffix("us").unwrap_or(rhs);
        let us: u64 = digits
            .parse()
            .map_err(|_| format!("bad reconfig time `{rhs}` (want microseconds, e.g. 200us)"))?;
        let kind = match lhs.split_once(':') {
            None => match lhs {
                "rejoin" => ReconfigKind::Rejoin,
                _ => return Err(format!("unknown reconfig kind `{lhs}` (it takes no `:arg`?)")),
            },
            Some(("reslice", n)) => {
                let n: usize =
                    n.parse().map_err(|_| format!("bad slice count in `{s}`"))?;
                if n == 0 {
                    return Err(format!("reslice target must be >= 1 in `{s}`"));
                }
                ReconfigKind::Reslice(n)
            }
            Some(("cache", b)) => ReconfigKind::CacheResize(parse_bytes(b)?),
            Some(("relmode", m)) | Some(("rel", m)) => ReconfigKind::RelSwap(
                RelMode::parse(m).ok_or_else(|| format!("bad rel mode `{m}` (gbn|sr)"))?,
            ),
            Some(("drain", d)) => ReconfigKind::Drain(
                d.parse().map_err(|_| format!("bad drain slice in `{s}`"))?,
            ),
            Some((k, _)) => {
                return Err(format!(
                    "unknown reconfig kind `{k}` (reslice|cache|relmode|drain|rejoin)"
                ))
            }
        };
        Ok(ReconfigEvent { at: Duration::from_us(us), kind })
    }

    /// Parse a comma-separated list of specs (the repeatable
    /// `--reconfig` flag also accepts one comma-joined value).
    pub fn parse_list(s: &str) -> Result<Vec<ReconfigEvent>, String> {
        s.split(',').filter(|p| !p.is_empty()).map(ReconfigEvent::parse).collect()
    }
}

/// Control-plane phase, surfaced as the `ctrl.phase` gauge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Data plane running free.
    Idle,
    /// A transition is draining the data plane; arrivals park.
    Quiescing,
}

/// What one executed (or skipped) transition did, for the report and
/// the `fig_reconfig` table.
#[derive(Clone, Debug)]
pub struct TransitionRecord {
    pub kind: ReconfigKind,
    /// Scripted start time.
    pub scheduled: Duration,
    /// When quiescing actually began (>= `scheduled` if an earlier
    /// transition was still in flight).
    pub quiesce_start: Time,
    /// When the data plane was quiet and the shape handoff executed.
    pub handoff_at: Time,
    /// When parked arrivals were released.
    pub resume_at: Time,
    /// Arrivals parked across the quiesce window.
    pub parked: u64,
    /// Directory lines exported/imported by the handoff.
    pub moved_lines: u64,
    /// Cached copies evicted (written back if dirty) because the new
    /// shape had no room for them.
    pub cache_victims: u64,
    /// The event fired after the run's completion target and did
    /// nothing.
    pub skipped: bool,
}

impl TransitionRecord {
    pub fn begun(ev: ReconfigEvent, now: Time) -> TransitionRecord {
        TransitionRecord {
            kind: ev.kind,
            scheduled: ev.at,
            quiesce_start: now,
            handoff_at: now,
            resume_at: now,
            parked: 0,
            moved_lines: 0,
            cache_victims: 0,
            skipped: false,
        }
    }

    pub fn skipped_at(ev: ReconfigEvent, now: Time) -> TransitionRecord {
        TransitionRecord { skipped: true, ..TransitionRecord::begun(ev, now) }
    }

    /// Quiesce-begin to handoff, µs.
    pub fn quiesce_us(&self) -> f64 {
        self.handoff_at.since(self.quiesce_start).ps() as f64 / 1e6
    }

    /// Quiesce-begin to resume — the window arrivals spent parked, µs.
    pub fn stall_us(&self) -> f64 {
        self.resume_at.since(self.quiesce_start).ps() as f64 / 1e6
    }
}

/// The control plane a host carries while running: the scripted
/// transitions, the canonical current shape, and the execution state.
///
/// The controller owns no RNG and schedules nothing itself — the host
/// drives it from its own event loop, so runs without a controller are
/// bit-identical to runs before the control plane existed.
pub struct Controller {
    /// The canonical "current shape". Every handoff mutates this spec
    /// first ([`Controller::apply`]), then the host re-derives the
    /// plane-level configs from it — there is exactly one place the
    /// running shape lives.
    pub spec: SystemSpec,
    /// Scripted transitions, sorted by fire time (stable: equal times
    /// keep script order).
    pub events: Vec<ReconfigEvent>,
    pub phase: Phase,
    /// Index (into `events`) of the transition currently quiescing.
    pub active: Option<usize>,
    /// Transitions that fired while another was quiescing; they begin,
    /// in order, at the in-flight one's resume.
    pub backlog: VecDeque<usize>,
    /// Execution-order records, one per fired event.
    pub records: Vec<TransitionRecord>,
    /// Counters absorbed from retired directory instances across
    /// rebuilds — counter continuity for telemetry and the final
    /// report.
    pub carried: Counters,
}

impl Controller {
    pub fn new(spec: SystemSpec, mut events: Vec<ReconfigEvent>) -> Controller {
        events.sort_by_key(|e| e.at);
        Controller {
            spec,
            events,
            phase: Phase::Idle,
            active: None,
            backlog: VecDeque::new(),
            records: Vec::new(),
            carried: Counters::new(),
        }
    }

    pub fn quiescing(&self) -> bool {
        self.phase == Phase::Quiescing
    }

    /// Mutate the canonical shape for one transition. Pure spec
    /// surgery — the host applies the derived configs to the data
    /// plane afterwards.
    pub fn apply(&mut self, kind: ReconfigKind) {
        match kind {
            ReconfigKind::Reslice(n) => {
                assert!(
                    self.spec.dead_slice.is_none(),
                    "re-slice with a drained slice outstanding (rejoin first)"
                );
                self.spec.slices = n;
            }
            ReconfigKind::CacheResize(bytes) => {
                self.spec.machine.home_cache_bytes = bytes;
                self.spec.home_cached = bytes > 0;
            }
            ReconfigKind::RelSwap(m) => {
                if let Some(rc) = &mut self.spec.machine.rel {
                    rc.mode = m;
                }
            }
            ReconfigKind::Drain(s) => {
                assert!(self.spec.dead_slice.is_none(), "drain with a slice already drained");
                self.spec.dead_slice = Some(s);
            }
            ReconfigKind::Rejoin => {
                assert!(self.spec.dead_slice.is_some(), "rejoin with no slice drained");
                self.spec.dead_slice = None;
            }
        }
    }

    /// Fold a retired data-plane's counters into the carried set.
    pub fn absorb(&mut self, retired: &Counters) {
        for (k, v) in retired.iter() {
            self.carried.add(k, v);
        }
    }
}

/// What the control plane did over one run.
#[derive(Clone, Debug, Default)]
pub struct ReconfigReport {
    /// Executed/skipped transitions, in execution order.
    pub transitions: Vec<TransitionRecord>,
    /// `(completion sim-time ps, latency ps)` per completed operation,
    /// in completion order — the `fig_reconfig` dip timeline.
    pub timeline: Vec<(u64, u64)>,
}

impl ReconfigReport {
    /// Transitions that actually executed (fired before the completion
    /// target).
    pub fn executed(&self) -> usize {
        self.transitions.iter().filter(|t| !t.skipped).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind_and_time_spelling() {
        let e = ReconfigEvent::parse("reslice:4@200us").unwrap();
        assert_eq!(e.at, Duration::from_us(200));
        assert_eq!(e.kind, ReconfigKind::Reslice(4));

        let e = ReconfigEvent::parse("cache:64k@50").unwrap();
        assert_eq!(e.at, Duration::from_us(50));
        assert_eq!(e.kind, ReconfigKind::CacheResize(64 * 1024));
        assert_eq!(
            ReconfigEvent::parse("cache:1m@1us").unwrap().kind,
            ReconfigKind::CacheResize(1024 * 1024)
        );
        assert_eq!(
            ReconfigEvent::parse("cache:0@1us").unwrap().kind,
            ReconfigKind::CacheResize(0)
        );

        let e = ReconfigEvent::parse("relmode:sr@300us").unwrap();
        assert_eq!(e.kind, ReconfigKind::RelSwap(RelMode::SelectiveRepeat));
        // `rel:` alias, and RelMode's own alias table
        let e = ReconfigEvent::parse("rel:go-back-n@300us").unwrap();
        assert_eq!(e.kind, ReconfigKind::RelSwap(RelMode::GoBackN));

        assert_eq!(
            ReconfigEvent::parse("drain:1@120us").unwrap().kind,
            ReconfigKind::Drain(1)
        );
        assert_eq!(ReconfigEvent::parse("rejoin@240us").unwrap().kind, ReconfigKind::Rejoin);
    }

    #[test]
    fn rejects_malformed_specs_loudly() {
        for bad in [
            "reslice:4",          // no time
            "reslice@200us",      // no target
            "reslice:0@200us",    // zero slices
            "reslice:x@200us",    // non-numeric
            "cache:64q@200us",    // bad suffix
            "relmode:tcp@200us",  // unknown mode
            "warp:9@200us",       // unknown kind
            "rejoin:1@200us",     // rejoin takes no arg
            "drain:one@200us",    // non-numeric slice
            "reslice:4@fastus",   // bad time
        ] {
            assert!(ReconfigEvent::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn label_round_trips_through_parse() {
        for spec in ["reslice:4", "cache:65536", "relmode:sr", "drain:1", "rejoin"] {
            let e = ReconfigEvent::parse(&format!("{spec}@10us")).unwrap();
            assert_eq!(e.kind.label(), *spec);
            let again = ReconfigEvent::parse(&format!("{}@10us", e.kind.label())).unwrap();
            assert_eq!(again.kind, e.kind);
        }
    }

    #[test]
    fn parse_list_splits_on_commas() {
        let evs = ReconfigEvent::parse_list("reslice:4@200us,rejoin@400us").unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].kind, ReconfigKind::Reslice(4));
        assert_eq!(evs[1].kind, ReconfigKind::Rejoin);
        assert!(ReconfigEvent::parse_list("reslice:4@200us,bogus").is_err());
    }

    #[test]
    fn controller_sorts_events_and_applies_shape_surgery() {
        let spec = SystemSpec::dcs_cached(2);
        let evs = vec![
            ReconfigEvent::parse("rejoin@400us").unwrap(),
            ReconfigEvent::parse("drain:1@100us").unwrap(),
        ];
        let mut c = Controller::new(spec, evs);
        assert_eq!(c.events[0].kind, ReconfigKind::Drain(1), "events sort by time");
        assert_eq!(c.phase, Phase::Idle);
        assert!(!c.quiescing());

        c.apply(ReconfigKind::Drain(1));
        assert_eq!(c.spec.dead_slice, Some(1));
        c.apply(ReconfigKind::Rejoin);
        assert_eq!(c.spec.dead_slice, None);
        c.apply(ReconfigKind::Reslice(4));
        assert_eq!(c.spec.slices, 4);
        c.apply(ReconfigKind::CacheResize(0));
        assert!(!c.spec.home_cached);
        assert_eq!(c.spec.machine.home_cache_bytes, 0);
    }

    #[test]
    #[should_panic(expected = "rejoin with no slice drained")]
    fn rejoin_without_drain_panics() {
        let mut c = Controller::new(SystemSpec::default(), Vec::new());
        c.apply(ReconfigKind::Rejoin);
    }

    #[test]
    fn carried_counters_accumulate_across_absorbs() {
        let mut c = Controller::new(SystemSpec::default(), Vec::new());
        let mut a = Counters::new();
        a.add("served", 10);
        c.absorb(&a);
        c.absorb(&a);
        assert_eq!(c.carried.get("served"), 20);
    }
}
