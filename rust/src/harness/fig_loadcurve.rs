//! Latency vs offered load: the open-loop hockey stick, per slice count.
//!
//! The closed-loop `fig_throughput` measures *sustained* throughput —
//! it can never overload the directory. This driver sweeps an open-loop
//! offered rate (`workload::openloop`) across directory slice counts
//! and reports the latency distribution (p50/p99/p999) at every point,
//! plus the **knee**: the highest offered rate the configuration still
//! sustains (delivered ≥ 85% of offered). Shape criterion: the knee
//! grows with the slice count while the slice pipeline is the
//! bottleneck, and under Zipf-skewed popularity the per-slice load skew
//! exceeds the uniform baseline — both asserted at CI scale below.
//!
//! The rate grid is geometric around the one-slice service capacity of
//! the streaming `scan` workload (one request + one release per
//! operation, [`base_rate`]), so the same grid shows 1-slice saturation
//! near multiplier 1.0 and leaves headroom for larger slice counts.

use crate::sim::time::Duration;
use crate::workload::openloop::{self, ClassLatency, OpenLoopConfig};
use crate::workload::scenario::Scenario;

use super::common::{fmt_rate, ResultTable, Scale};

/// Slice counts swept by default (the same sweep as `fig_throughput`,
/// so closed- and open-loop results line up point for point).
pub use super::fig_throughput::SLICE_SWEEP;

/// Offered-rate multipliers relative to [`base_rate`].
pub const RATE_MULTIPLIERS: [f64; 8] = [0.08, 0.16, 0.33, 0.66, 1.0, 1.6, 2.9, 5.2];

/// A point is "sustained" when delivered ≥ this fraction of offered.
pub const SUSTAINED_FRACTION: f64 = 0.85;

/// Arrivals per sweep point at each scale.
pub fn ops_for(scale: Scale) -> u64 {
    match scale {
        Scale::Ci => 2_500,
        Scale::Default => 12_000,
        Scale::Paper => 60_000,
    }
}

/// Scenario footprint sizing (base lines handed to [`Scenario::preset`]).
pub fn footprint_for(scale: Scale) -> u64 {
    match scale {
        Scale::Ci => 1 << 12,
        Scale::Default => 1 << 14,
        Scale::Paper => 1 << 16,
    }
}

/// Estimated one-slice *operation* capacity of the streaming scan
/// workload: each op costs ~2 slice messages (request + voluntary
/// release), so capacity ≈ 1 / (2 × slice_proc).
pub fn base_rate(slice_proc: Duration) -> f64 {
    0.5 / slice_proc.as_secs()
}

/// The default offered-rate grid for a machine's slice pipeline.
pub fn default_rates(slice_proc: Duration) -> Vec<f64> {
    let base = base_rate(slice_proc);
    RATE_MULTIPLIERS.iter().map(|m| m * base).collect()
}

#[derive(Clone, Debug)]
pub struct LoadCurvePoint {
    pub offered_per_s: f64,
    pub delivered_per_s: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub p999_ns: f64,
    pub credit_stalls: u64,
    pub peak_tx_queue: usize,
    pub served_skew: f64,
    /// Per-traffic-class latency breakdown at this point (one entry per
    /// scenario class; see [`render_classes`]).
    pub per_class: Vec<ClassLatency>,
}

impl LoadCurvePoint {
    pub fn sustained(&self) -> bool {
        self.delivered_per_s >= SUSTAINED_FRACTION * self.offered_per_s
    }
}

/// One latency-vs-load curve (fixed slice count, swept rate).
#[derive(Clone, Debug)]
pub struct LoadCurve {
    pub slices: usize,
    /// Slice-local home caches present (`OpenLoopConfig::home_cached`)?
    pub home_cached: bool,
    pub points: Vec<LoadCurvePoint>,
    /// Saturation rate: the highest sustained offered rate.
    pub knee_per_s: f64,
}

pub struct FigLoadCurve {
    pub scenario: String,
    pub curves: Vec<LoadCurve>,
}

/// One sweep point: `scenario` at `rate` ops/s against `slices` slices.
pub fn run_point(
    cfg: OpenLoopConfig,
    scenario: &Scenario,
    slices: usize,
    rate: f64,
) -> LoadCurvePoint {
    let cfg = OpenLoopConfig { rate_per_s: rate, ..cfg };
    let r = openloop::run(cfg, scenario, slices);
    LoadCurvePoint {
        offered_per_s: r.offered_per_s,
        delivered_per_s: r.delivered_per_s,
        p50_ns: r.p50_ns(),
        p99_ns: r.p99_ns(),
        p999_ns: r.p999_ns(),
        credit_stalls: r.credit_stalls,
        peak_tx_queue: r.peak_tx_queue,
        served_skew: r.served_skew,
        per_class: r.per_class,
    }
}

/// Knee of a rate-sorted curve: the highest sustained offered rate, or
/// 0.0 when even the lowest swept rate overloads the configuration (a
/// rate that was never sustained must not be reported as a knee).
pub fn knee_of(points: &[LoadCurvePoint]) -> f64 {
    let best = points
        .iter()
        .filter(|p| p.sustained())
        .map(|p| p.offered_per_s)
        .fold(f64::NEG_INFINITY, f64::max);
    if best.is_finite() {
        best
    } else {
        0.0
    }
}

/// Sweep one slice count over the rate grid.
pub fn run_curve(
    cfg: OpenLoopConfig,
    scenario: &Scenario,
    slices: usize,
    rates: &[f64],
) -> LoadCurve {
    let points: Vec<LoadCurvePoint> =
        rates.iter().map(|&r| run_point(cfg, scenario, slices, r)).collect();
    let knee_per_s = knee_of(&points);
    LoadCurve { slices, home_cached: cfg.home_cached, points, knee_per_s }
}

/// Full figure: every slice count over the same scenario and rate grid.
pub fn run_custom(
    cfg: OpenLoopConfig,
    scenario: &Scenario,
    slices: &[usize],
    rates: &[f64],
) -> FigLoadCurve {
    run_custom_with(cfg, scenario, slices, &[], rates)
}

/// Full figure with cached configurations: `slices` runs as configured,
/// `cached_slices` additionally runs with slice-local home caches
/// (`home_cached`) — the `eci bench workload --cached-slices` surface.
pub fn run_custom_with(
    cfg: OpenLoopConfig,
    scenario: &Scenario,
    slices: &[usize],
    cached_slices: &[usize],
    rates: &[f64],
) -> FigLoadCurve {
    let mut curves: Vec<LoadCurve> =
        slices.iter().map(|&n| run_curve(cfg, scenario, n, rates)).collect();
    let cached_cfg = OpenLoopConfig { home_cached: true, ..cfg };
    curves.extend(cached_slices.iter().map(|&n| run_curve(cached_cfg, scenario, n, rates)));
    FigLoadCurve { scenario: scenario.name.clone(), curves }
}

/// The default figure: the multi-tenant scenario (θ=0.99 hot tenant),
/// slice counts 1/2/4/8, rate grid around 1-slice capacity.
pub fn run(scale: Scale) -> FigLoadCurve {
    let cfg = OpenLoopConfig { ops: ops_for(scale), ..Default::default() };
    let scenario =
        Scenario::preset("tenants", footprint_for(scale), 0.99).expect("tenants preset");
    let rates = default_rates(cfg.machine.home_proc);
    run_custom(cfg, &scenario, &SLICE_SWEEP, &rates)
}

pub fn render(f: &FigLoadCurve) -> ResultTable {
    let mut t = ResultTable::new(
        &format!("Latency vs offered load, scenario `{}` (open loop, framed admission)", f.scenario),
        &[
            "slices",
            "config",
            "offered/s",
            "delivered/s",
            "p50 ns",
            "p99 ns",
            "p999 ns",
            "credit stalls",
            "peak txq",
            "skew",
            "sustained",
        ],
    );
    for c in &f.curves {
        for p in &c.points {
            t.row(vec![
                c.slices.to_string(),
                if c.home_cached { "cached".into() } else { "plain".into() },
                fmt_rate(p.offered_per_s),
                fmt_rate(p.delivered_per_s),
                format!("{:.0}", p.p50_ns),
                format!("{:.0}", p.p99_ns),
                format!("{:.0}", p.p999_ns),
                p.credit_stalls.to_string(),
                p.peak_tx_queue.to_string(),
                format!("{:.2}", p.served_skew),
                if p.sustained() { "yes".into() } else { "NO".into() },
            ]);
        }
    }
    t
}

/// Per-class latency breakdown: p50/p99/p999 for every traffic class at
/// every sweep point (printed by `eci bench workload` — under
/// multi-tenant scenarios this is where one tenant's overload shows up
/// in another tenant's tail).
pub fn render_classes(f: &FigLoadCurve) -> ResultTable {
    let mut t = ResultTable::new(
        &format!("Per-class latency breakdown, scenario `{}`", f.scenario),
        &[
            "slices",
            "config",
            "offered/s",
            "class",
            "completed",
            "p50 ns",
            "p99 ns",
            "p999 ns",
        ],
    );
    for c in &f.curves {
        for p in &c.points {
            for cl in &p.per_class {
                t.row(vec![
                    c.slices.to_string(),
                    if c.home_cached { "cached".into() } else { "plain".into() },
                    fmt_rate(p.offered_per_s),
                    cl.class.clone(),
                    cl.completed.to_string(),
                    format!("{:.0}", cl.p50_ns()),
                    format!("{:.0}", cl.p99_ns()),
                    format!("{:.0}", cl.p999_ns()),
                ]);
            }
        }
    }
    t
}

/// Knee summary: saturation rate per slice count.
pub fn render_knees(f: &FigLoadCurve) -> ResultTable {
    let mut t = ResultTable::new(
        &format!("Saturation knee vs slice count, scenario `{}`", f.scenario),
        &["slices", "config", "knee (sustained ops/s)"],
    );
    for c in &f.curves {
        let knee = if c.knee_per_s > 0.0 {
            fmt_rate(c.knee_per_s)
        } else {
            "none sustained".into()
        };
        t.row(vec![
            c.slices.to_string(),
            if c.home_cached { "cached".into() } else { "plain".into() },
            knee,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dcs::loadgen::MixConfig;
    use crate::workload::scenario::{Popularity, TrafficClass};

    /// Acceptance: the saturation knee must grow with the slice count
    /// (CI scale, streaming scan traffic — 2 directory messages/op).
    #[test]
    fn knee_grows_with_slice_count() {
        let cfg = OpenLoopConfig { ops: ops_for(Scale::Ci), ..Default::default() };
        let scenario = Scenario::preset("scan", footprint_for(Scale::Ci), 0.99).unwrap();
        let rates = default_rates(cfg.machine.home_proc);
        let f = run_custom(cfg, &scenario, &[1, 4], &rates);
        let k1 = f.curves[0].knee_per_s;
        let k4 = f.curves[1].knee_per_s;
        // the 1-slice curve must actually saturate inside the sweep ...
        let top = rates.last().copied().unwrap();
        assert!(k1 < top * 0.99, "1-slice knee {k1} never saturated (top {top})");
        // ... and 4 slices must push the knee substantially further out
        assert!(k4 >= 1.5 * k1, "knee did not grow with slices: 1 -> {k1}, 4 -> {k4}");
        // curve sanity: lowest rate is sustained, tails are ordered
        for c in &f.curves {
            assert!(c.points[0].sustained(), "lowest rate must be sustained");
            for p in &c.points {
                assert!(p.p999_ns >= p.p99_ns && p.p99_ns >= p.p50_ns);
            }
        }
        // overload points must show credit backpressure, not silence
        let worst = f.curves[0].points.last().unwrap();
        assert!(!worst.sustained());
        assert!(worst.credit_stalls > 0 && worst.peak_tx_queue > 100);
    }

    /// Acceptance: Zipf θ=0.99 popularity must load directory slices
    /// measurably less evenly than uniform popularity (CI scale).
    #[test]
    fn zipf_hotspot_skew_beats_uniform() {
        let probe = |popularity| {
            let cls = TrafficClass {
                name: "probe".into(),
                rate_weight: 1,
                mix: MixConfig::read_only(),
                footprint_lines: 1 << 12,
                popularity,
            };
            let cfg = OpenLoopConfig { rate_per_s: 3e6, ops: 4_000, ..Default::default() };
            openloop::run(cfg, &Scenario::new("skew-probe", vec![cls]), 4)
        };
        let uni = probe(Popularity::Uniform);
        let zipf = probe(Popularity::Zipf { theta: 0.99 });
        assert!(uni.served_skew < 1.12, "uniform skew unexpectedly high: {}", uni.served_skew);
        assert!(
            zipf.served_skew > 1.15,
            "zipf 0.99 skew too low to matter: {}",
            zipf.served_skew
        );
        assert!(
            zipf.served_skew > uni.served_skew * 1.1,
            "zipf {} vs uniform {}",
            zipf.served_skew,
            uni.served_skew
        );
        // occupancy skew tells the same hot-spot story
        assert!(zipf.occupancy_skew > uni.occupancy_skew);
    }

    #[test]
    fn render_has_one_row_per_point_and_a_knee_per_curve() {
        let cfg = OpenLoopConfig { ops: 400, ..Default::default() };
        let scenario = Scenario::preset("scan", 1 << 10, 0.99).unwrap();
        let f = run_custom(cfg, &scenario, &[1, 2], &[2e6, 8e6]);
        let t = render(&f);
        assert_eq!(t.rows.len(), 4);
        assert!(t.to_markdown().contains("p999 ns"));
        let k = render_knees(&f);
        assert_eq!(k.rows.len(), 2);
        // scan is single-class: one breakdown row per sweep point
        let cls = render_classes(&f);
        assert_eq!(cls.rows.len(), 4);
        assert!(cls.to_markdown().contains("scan"));
    }

    /// Cached curves ride the same sweep: on hot-kvs traffic the cached
    /// configuration's sub-knee latency beats cache-less slices at equal
    /// slice count (the knee itself is pipeline-bound, so it is latency
    /// where the home cache shows in the open loop).
    #[test]
    fn cached_slices_cut_subknee_latency_on_hot_kvs() {
        let cfg = OpenLoopConfig { ops: 1_500, ..Default::default() };
        let scenario = Scenario::preset("hot-kvs", 1 << 12, 0.99).unwrap();
        // one comfortably sub-knee rate for 2 slices
        let rate = 0.3 * base_rate(cfg.machine.home_proc);
        let f = run_custom_with(cfg, &scenario, &[2], &[2], &[rate]);
        assert_eq!(f.curves.len(), 2);
        let plain = f.curves.iter().find(|c| !c.home_cached).unwrap();
        let cached = f.curves.iter().find(|c| c.home_cached).unwrap();
        assert_eq!(plain.slices, cached.slices);
        assert!(plain.points[0].sustained() && cached.points[0].sustained());
        assert!(
            cached.points[0].p50_ns < plain.points[0].p50_ns,
            "cached p50 {} must beat plain {}",
            cached.points[0].p50_ns,
            plain.points[0].p50_ns
        );
        let md = render_knees(&f).to_markdown();
        assert!(md.contains("cached") && md.contains("plain"));
    }
}
