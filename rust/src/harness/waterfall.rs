//! Latency waterfall: where a request's time goes, stage by stage.
//!
//! `eci bench workload --spans` runs one observed open-loop point per
//! slice count and decomposes the end-to-end latency of sampled
//! transactions into the six lifecycle intervals tracked by
//! [`crate::obs::span`] — ingress wait, wire transit, slice queueing,
//! home service, memory backend, reply delivery. The stages telescope:
//! per-span they sum exactly to the end-to-end time, so the rendered
//! table carries a `sum(stages)` row that must (and does) match the
//! `end_to_end` row's mean to float precision — the acceptance check
//! for the span plumbing itself.

use crate::obs::{ObsConfig, ObsReport, Waterfall};
use crate::sim::time::Duration;
use crate::workload::openloop::{OpenLoop, OpenLoopConfig, OpenLoopReport};
use crate::workload::scenario::Scenario;

use super::common::ResultTable;

/// Default telemetry snapshot interval for `--obs-out`.
pub const DEFAULT_TICK: Duration = Duration::from_us(10);

/// One observed open-loop run at a fixed slice count.
pub fn run_observed(
    cfg: OpenLoopConfig,
    scenario: &Scenario,
    slices: usize,
    ocfg: &ObsConfig,
) -> (OpenLoopReport, ObsReport) {
    OpenLoop::new(cfg, scenario, slices).with_obs(ocfg).run_observed()
}

/// Render one configuration's waterfall as a table: one row per stage,
/// then the stage sum, then the end-to-end distribution it must match.
pub fn render(slices: usize, w: &Waterfall) -> ResultTable {
    render_titled(&format!("{slices} slice(s)"), w)
}

/// [`render`] with a caller-supplied configuration label (the fabric
/// bench renders per node count rather than per slice count).
pub fn render_titled(what: &str, w: &Waterfall) -> ResultTable {
    let mut t = ResultTable::new(
        &format!(
            "Latency waterfall, {what} — {} sampled / {} completed spans \
             ({} remote, {} retransmit episodes, {} incomplete)",
            w.sampled,
            w.completed + w.remote_completed,
            w.remote_completed,
            w.retx_episodes,
            w.incomplete
        ),
        &["stage", "count", "mean ns", "p50 ns", "p99 ns"],
    );
    for r in &w.rows {
        t.row(vec![
            r.stage.to_string(),
            r.count.to_string(),
            format!("{:.1}", r.mean_ns),
            format!("{:.1}", r.p50_ns),
            format!("{:.1}", r.p99_ns),
        ]);
    }
    t.row(vec![
        "sum(stages)".into(),
        w.completed.to_string(),
        format!("{:.1}", w.stage_mean_sum_ns()),
        "-".into(),
        "-".into(),
    ]);
    t.row(vec![
        "end_to_end".into(),
        w.e2e.count.to_string(),
        format!("{:.1}", w.e2e.mean_ns),
        format!("{:.1}", w.e2e.p50_ns),
        format!("{:.1}", w.e2e.p99_ns),
    ]);
    // the remote-fill class (multi-node runs): same layout, its own
    // telescoping sum against its own end-to-end row
    if let Some(er) = &w.e2e_remote {
        for r in &w.remote_rows {
            t.row(vec![
                format!("remote.{}", r.stage),
                r.count.to_string(),
                format!("{:.1}", r.mean_ns),
                format!("{:.1}", r.p50_ns),
                format!("{:.1}", r.p99_ns),
            ]);
        }
        t.row(vec![
            "remote.sum(stages)".into(),
            w.remote_completed.to_string(),
            format!("{:.1}", w.remote_stage_mean_sum_ns()),
            "-".into(),
            "-".into(),
        ]);
        t.row(vec![
            "remote.end_to_end".into(),
            er.count.to_string(),
            format!("{:.1}", er.mean_ns),
            format!("{:.1}", er.p50_ns),
            format!("{:.1}", er.p99_ns),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observed_point_renders_a_consistent_waterfall() {
        let cfg = OpenLoopConfig { ops: 600, ..Default::default() };
        let scenario = Scenario::preset("scan", 1 << 10, 0.99).unwrap();
        let ocfg = ObsConfig::with_spans();
        let (r, obs) = run_observed(cfg, &scenario, 2, &ocfg);
        assert_eq!(r.completed, 600);
        let w = obs.waterfall.expect("spans were on");
        assert!(w.completed > 0);
        assert_eq!(w.rows.len(), crate::obs::STAGE_NAMES.len());
        let t = render(2, &w);
        // stage rows + sum row + end-to-end row
        assert_eq!(t.rows.len(), w.rows.len() + 2);
        let md = t.to_markdown();
        assert!(md.contains("home_service") && md.contains("end_to_end"));
        // the telescoping invariant, as rendered
        let sum = w.stage_mean_sum_ns();
        assert!(
            (sum - w.e2e.mean_ns).abs() <= 1e-6 * w.e2e.mean_ns.max(1.0),
            "stage means {sum} do not telescope to e2e {}",
            w.e2e.mean_ns
        );
    }
}
