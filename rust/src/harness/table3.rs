//! Table 3: inter-socket throughput and latency, Enzian+ECI vs native
//! 2-socket server.
//!
//! Paper: ECI 12.8 GiB/s / 320 ns; native 19 GiB/s / 150 ns. Shape
//! criterion: native wins both axes by ~1.5x (throughput) and ~2.1x
//! (latency); ECI remains the same order of magnitude ("realistic
//! performance for cache coherence hardware").

use crate::agents::dram::MemStore;
use crate::machine::{map, Machine, MachineConfig, Workload};
use crate::proto::messages::LINE_BYTES;

use super::common::{ResultTable, Scale};

#[derive(Clone, Copy, Debug)]
pub struct Table3Row {
    pub throughput_gib: f64,
    pub latency_ns: f64,
}

/// Run both microbenchmarks on one machine configuration.
pub fn run_config(cfg: MachineConfig, scale: Scale) -> Table3Row {
    // throughput: all threads stream the remote region
    let lines = scale.rows(2_000_000);
    let region_bytes = (lines as usize + 1024) * LINE_BYTES;
    let fpga = MemStore::new(map::TABLE_BASE, region_bytes);
    let cpu = MemStore::new(crate::proto::messages::LineAddr(0), 1 << 20);
    let mut m = Machine::memory_node(cfg, fpga, cpu);
    m.set_workload(Workload::StreamRemote { lines }, cfg.cpu.cores.min(48));
    let r = m.run();
    let throughput_gib = r.remote_gib_per_s();

    // latency: single-thread dependent loads over a region 8x the LLC
    // (~88% cold misses; we report the p50, which is a miss). The region
    // is materialized (the home agent reads real payload bytes) but
    // allocated zeroed, so untouched pages stay shared.
    let chase_lines: u64 = 1 << 20; // 128 MiB
    let fpga = MemStore::new(map::TABLE_BASE, (chase_lines as usize) * LINE_BYTES);
    let cpu = MemStore::new(crate::proto::messages::LineAddr(0), 1 << 20);
    let mut m = Machine::memory_node(cfg, fpga, cpu);
    let count = match scale {
        Scale::Ci => 2_000,
        Scale::Default => 20_000,
        Scale::Paper => 200_000,
    };
    m.set_workload(Workload::ChaseRemote { count, region_lines: chase_lines }, 1);
    let r = m.run();
    Table3Row { throughput_gib, latency_ns: r.load_lat.p50() as f64 / 1000.0 }
}

pub struct Table3 {
    pub eci: Table3Row,
    pub native: Table3Row,
}

pub fn run(scale: Scale) -> Table3 {
    Table3 {
        eci: run_config(MachineConfig::enzian_eci(), scale),
        native: run_config(MachineConfig::native_2socket(), scale),
    }
}

pub fn render(t: &Table3) -> ResultTable {
    let mut out = ResultTable::new(
        "Table 3: ECI performance comparison (paper: ECI 12.8 GiB/s / 320 ns, native 19 GiB/s / 150 ns)",
        &["", "Enzian + ECI", "2-socket server (native)"],
    );
    out.row(vec![
        "Throughput".into(),
        format!("{:.1} GiB/s", t.eci.throughput_gib),
        format!("{:.1} GiB/s", t.native.throughput_gib),
    ]);
    out.row(vec![
        "Latency".into(),
        format!("{:.0} ns", t.eci.latency_ns),
        format!("{:.0} ns", t.native.latency_ns),
    ]);
    out
}
