//! Table 3: inter-socket throughput and latency, Enzian+ECI vs native
//! 2-socket server.
//!
//! Paper: ECI 12.8 GiB/s / 320 ns; native 19 GiB/s / 150 ns. Shape
//! criterion: native wins both axes by ~1.5x (throughput) and ~2.1x
//! (latency); ECI remains the same order of magnitude ("realistic
//! performance for cache coherence hardware").
//!
//! The *sliced* variant ([`run_sliced`]) re-runs the same two
//! microbenchmarks against [`Machine::dcs_node`] — the
//! finite-throughput sharded directory instead of the
//! unbounded-concurrency home — swept over slice counts. It answers the
//! question Table 3 cannot: how many directory pipelines does the FPGA
//! need before the *link*, not the directory, is the bottleneck again.

use crate::agents::dram::MemStore;
use crate::machine::{map, Machine, MachineConfig, Workload};
use crate::proto::messages::LINE_BYTES;

use super::common::{ResultTable, Scale};

#[derive(Clone, Copy, Debug)]
pub struct Table3Row {
    pub throughput_gib: f64,
    pub latency_ns: f64,
}

/// Run both microbenchmarks on one machine built by `mk`.
fn run_machine(
    mk: impl Fn(MachineConfig, MemStore, MemStore) -> Machine,
    cfg: MachineConfig,
    scale: Scale,
) -> Table3Row {
    // throughput: all threads stream the remote region
    let lines = scale.rows(2_000_000);
    let region_bytes = (lines as usize + 1024) * LINE_BYTES;
    let fpga = MemStore::new(map::TABLE_BASE, region_bytes);
    let cpu = MemStore::new(crate::proto::messages::LineAddr(0), 1 << 20);
    let mut m = mk(cfg, fpga, cpu);
    m.set_workload(Workload::StreamRemote { lines }, cfg.cpu.cores.min(48));
    let r = m.run();
    let throughput_gib = r.remote_gib_per_s();

    // latency: single-thread dependent loads over a region 8x the LLC
    // (~88% cold misses; we report the p50, which is a miss). The region
    // is materialized (the home agent reads real payload bytes) but
    // allocated zeroed, so untouched pages stay shared.
    let chase_lines: u64 = 1 << 20; // 128 MiB
    let fpga = MemStore::new(map::TABLE_BASE, (chase_lines as usize) * LINE_BYTES);
    let cpu = MemStore::new(crate::proto::messages::LineAddr(0), 1 << 20);
    let mut m = mk(cfg, fpga, cpu);
    let count = match scale {
        Scale::Ci => 2_000,
        Scale::Default => 20_000,
        Scale::Paper => 200_000,
    };
    m.set_workload(Workload::ChaseRemote { count, region_lines: chase_lines }, 1);
    let r = m.run();
    Table3Row { throughput_gib, latency_ns: r.load_lat.p50() as f64 / 1000.0 }
}

/// Run both microbenchmarks on one machine configuration (monolithic
/// home node, the paper's configuration).
pub fn run_config(cfg: MachineConfig, scale: Scale) -> Table3Row {
    run_machine(Machine::memory_node, cfg, scale)
}

/// The sliced row: same microbenchmarks, FPGA running the sharded
/// directory controller with `slices` slices.
pub fn run_dcs_point(cfg: MachineConfig, slices: usize, scale: Scale) -> Table3Row {
    run_machine(|c, f, m| Machine::dcs_node(c, slices, f, m), cfg, scale)
}

pub struct Table3 {
    pub eci: Table3Row,
    pub native: Table3Row,
}

pub fn run(scale: Scale) -> Table3 {
    Table3 {
        eci: run_config(MachineConfig::enzian_eci(), scale),
        native: run_config(MachineConfig::native_2socket(), scale),
    }
}

/// Slice counts swept in the sliced Table-3 row.
pub const DCS_SLICE_SWEEP: [usize; 3] = [1, 2, 4];

pub struct Table3Sliced {
    pub rows: Vec<(usize, Table3Row)>,
}

/// Sweep `Machine::dcs_node` over [`DCS_SLICE_SWEEP`] on the Enzian+ECI
/// configuration.
pub fn run_sliced(scale: Scale) -> Table3Sliced {
    run_sliced_with(MachineConfig::enzian_eci(), &DCS_SLICE_SWEEP, scale)
}

pub fn run_sliced_with(cfg: MachineConfig, slices: &[usize], scale: Scale) -> Table3Sliced {
    Table3Sliced {
        rows: slices.iter().map(|&n| (n, run_dcs_point(cfg, n, scale))).collect(),
    }
}

pub fn render(t: &Table3) -> ResultTable {
    let mut out = ResultTable::new(
        "Table 3: ECI performance comparison (paper: ECI 12.8 GiB/s / 320 ns, native 19 GiB/s / 150 ns)",
        &["", "Enzian + ECI", "2-socket server (native)"],
    );
    out.row(vec![
        "Throughput".into(),
        format!("{:.1} GiB/s", t.eci.throughput_gib),
        format!("{:.1} GiB/s", t.native.throughput_gib),
    ]);
    out.row(vec![
        "Latency".into(),
        format!("{:.0} ns", t.eci.latency_ns),
        format!("{:.0} ns", t.native.latency_ns),
    ]);
    out
}

pub fn render_sliced(t: &Table3Sliced) -> ResultTable {
    let mut out = ResultTable::new(
        "Table 3 (sliced): Enzian + ECI with the sharded directory controller",
        &["slices", "Throughput", "Latency"],
    );
    for (n, row) in &t.rows {
        out.row(vec![
            n.to_string(),
            format!("{:.1} GiB/s", row.throughput_gib),
            format!("{:.0} ns", row.latency_ns),
        ]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The sliced row must run end to end and slicing must never *hurt*
    /// the single-outstanding-load latency (a line still maps to exactly
    /// one slice; contention only falls with more slices).
    #[test]
    fn sliced_row_completes_and_stays_sane() {
        let t = run_sliced_with(MachineConfig::enzian_eci(), &[1, 2], Scale::Ci);
        assert_eq!(t.rows.len(), 2);
        for (n, row) in &t.rows {
            assert!(*n >= 1);
            assert!(row.throughput_gib > 0.0, "no throughput at {n} slices");
            assert!(row.latency_ns > 0.0, "no latency at {n} slices");
        }
        let (_, one) = t.rows[0];
        let (_, two) = t.rows[1];
        // more slices must not slow the stream (equal is fine once the
        // link, not the directory, binds)
        assert!(
            two.throughput_gib >= one.throughput_gib * 0.95,
            "2 slices {} GiB/s < 1 slice {} GiB/s",
            two.throughput_gib,
            one.throughput_gib
        );
        let md = render_sliced(&t).to_markdown();
        assert!(md.contains("slices"));
    }
}
