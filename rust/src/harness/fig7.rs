//! Figure 7: regular-expression throughput vs. thread count and
//! selectivity (paper §5.6).
//!
//! Shape criteria: the FPGA wins in *every* configuration thanks to 48
//! pipelined 1-char/cycle engines; ~2x the 48-thread CPU even at 100%
//! selectivity (interconnect-bound), and it does so with a fraction of
//! the CPU threads involved.

use crate::agents::dram::MemStore;
use crate::anyhow;
use crate::machine::{map, FpgaApp, Machine, MachineConfig, Workload};
use crate::memctl::{regex_row_cycles, FifoServer, ScanTiming};
use crate::operators::redfa::compile_regex;
use crate::operators::regex_op::{cpu_regex_scan, fpga_regex_scan};
use crate::operators::table::{build_table, row_str, TableSpec};
use crate::proto::messages::{LineAddr, LINE_BYTES};
use crate::runtime::{Runtime, DFA_STATES};
use crate::sim::time::Duration;

use super::common::{fmt_rate, ResultTable, Scale};
use super::fig5::FigPoint;

pub const PAPER_ROWS: u64 = 5_120_000;
pub const FPGA_ENGINES: u32 = 48;
/// CPU cycles per row for the software matcher. The paper's CPU baseline
/// is a byte-at-a-time software regex library (kokke tiny-regex-c-class,
/// backtracking per start position): ~30 cycles/char over a 62-byte
/// field.
pub const CPU_CYCLES_PER_ROW: u64 = 30 * 62;
pub const CPU_MATCH_EXTRA: u64 = 32;

/// Precomputed per-selectivity scan (PERF: one XLA scan + one cycle pass
/// per selectivity, reused across the thread sweep — DESIGN.md §Perf).
pub struct PreparedRegex {
    pub rows: u64,
    pub selectivity: f64,
    store: MemStore,
    matches: Vec<u64>,
    cycles: std::rc::Rc<Vec<u64>>,
}

pub fn prepare(rt: &mut Runtime, rows: u64, selectivity: f64) -> anyhow::Result<PreparedRegex> {
    let mut spec = TableSpec::new(rows, selectivity);
    spec.regex_selectivity = selectivity;
    let mut store = MemStore::new(map::TABLE_BASE, rows as usize * LINE_BYTES);
    build_table(&spec, &mut store);
    let dfa = compile_regex(&spec.needle, DFA_STATES)?;
    let matches = fpga_regex_scan(rt, &store, map::TABLE_BASE, rows, &dfa)?;
    // per-row engine cycles: 1 char/cycle with early termination on match
    let cycles: Vec<u64> = (0..rows)
        .map(|i| {
            let l = store.read_line(LineAddr(map::TABLE_BASE.0 + i));
            regex_row_cycles(&dfa, row_str(&l))
        })
        .collect();
    Ok(PreparedRegex { rows, selectivity, store, matches, cycles: std::rc::Rc::new(cycles) })
}

pub fn run_fpga_prepared(p: &PreparedRegex, threads: usize) -> FigPoint {
    let rows = p.rows;
    let payloads: Vec<_> = p
        .matches
        .iter()
        .map(|&i| Box::new(p.store.read_line(LineAddr(map::TABLE_BASE.0 + i))))
        .collect();
    let cycles = std::rc::Rc::clone(&p.cycles);
    let fifo = FifoServer::new(
        rows,
        p.matches.clone(),
        payloads,
        move |r| cycles[r as usize],
        ScanTiming::enzian(FPGA_ENGINES),
        64 << 10,
    );
    let total_results = fifo.total_results() as u64;

    let cfg = MachineConfig::enzian_eci();
    let cpu_mem = MemStore::new(LineAddr(0), 1 << 20);
    let mut m = Machine::new(cfg, FpgaApp::Fifo(fifo), p.store.clone(), cpu_mem);
    m.set_workload(Workload::FifoConsume { think: Duration::from_ns(5) }, threads);
    let r = m.run();
    assert_eq!(r.results, total_results);
    FigPoint {
        selectivity: p.selectivity,
        threads,
        scan_rows_per_s: rows as f64 / r.sim_time.as_secs(),
        results_per_s: r.results_per_s(),
        dram_gbps: rows as f64 * 128.0 / r.sim_time.as_secs() / 1e9,
    }
}

/// FPGA-offload run (standalone).
pub fn run_fpga(
    rt: &mut Runtime,
    rows: u64,
    selectivity: f64,
    threads: usize,
) -> anyhow::Result<FigPoint> {
    Ok(run_fpga_prepared(&prepare(rt, rows, selectivity)?, threads))
}

/// CPU-only run.
pub fn run_cpu(rows: u64, selectivity: f64, threads: usize) -> anyhow::Result<FigPoint> {
    let mut spec = TableSpec::new(rows, selectivity);
    spec.regex_selectivity = selectivity;
    let mut store = MemStore::new(LineAddr(0), rows as usize * LINE_BYTES);
    build_table(&spec, &mut store);
    let dfa = compile_regex(&spec.needle, DFA_STATES)?;
    let matches = cpu_regex_scan(&store, LineAddr(0), rows, &dfa);
    let mut mask = vec![false; rows as usize];
    for &i in &matches {
        mask[i as usize] = true;
    }
    let cfg = MachineConfig::enzian_eci();
    let fpga_mem = MemStore::new(map::TABLE_BASE, 1 << 20);
    let mut m = Machine::memory_node(cfg, fpga_mem, store);
    m.set_workload(
        Workload::LocalScan {
            rows,
            cycles_per_row: CPU_CYCLES_PER_ROW,
            match_extra: CPU_MATCH_EXTRA,
            matches: mask,
        },
        threads,
    );
    let r = m.run();
    Ok(FigPoint {
        selectivity,
        threads,
        scan_rows_per_s: r.rows_per_s(),
        results_per_s: r.results as f64 / r.sim_time.as_secs(),
        dram_gbps: r.rows_per_s() * 128.0 / 1e9,
    })
}

pub struct Fig7 {
    pub fpga: Vec<FigPoint>,
    pub cpu: Vec<FigPoint>,
}

pub fn run(rt: &mut Runtime, scale: Scale) -> anyhow::Result<Fig7> {
    let rows = scale.rows(PAPER_ROWS);
    let mut fpga = Vec::new();
    let mut cpu = Vec::new();
    for &sel in &[0.01, 0.10, 1.00] {
        let prepared = prepare(rt, rows, sel)?;
        for &t in &scale.threads() {
            fpga.push(run_fpga_prepared(&prepared, t));
            cpu.push(run_cpu(rows, sel, t)?);
        }
    }
    Ok(Fig7 { fpga, cpu })
}

pub fn render(f: &Fig7) -> ResultTable {
    let mut t = ResultTable::new(
        "Figure 7: regex throughput vs. thread count and selectivity",
        &["impl", "selectivity", "threads", "scan rows/s", "results/s"],
    );
    for (name, pts) in [("FPGA", &f.fpga), ("CPU", &f.cpu)] {
        for p in pts.iter() {
            t.row(vec![
                name.into(),
                format!("{:.0}%", p.selectivity * 100.0),
                p.threads.to_string(),
                fmt_rate(p.scan_rows_per_s),
                fmt_rate(p.results_per_s),
            ]);
        }
    }
    t
}
