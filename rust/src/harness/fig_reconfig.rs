//! fig_reconfig — live-reconfiguration cost: what does an online shape
//! change do to the latency tail, and for how long?
//!
//! One open-loop run executes a scripted transition sequence (re-slice,
//! cache resize, rel-mode swap, drain/rejoin — see [`crate::ctrl`]);
//! the per-completion timeline the control plane records is bucketed
//! into windows and each transition gets a **p99 dip summary**: the
//! steady-state p99 before quiescing began, the worst windowed p99
//! after it, the depth of that excursion, and how long the tail stayed
//! elevated. Parked-arrival counts and handoff volume (lines moved,
//! cache victims) land in the same row, so the table reads as "this
//! transition cost this much tail for this long".

use crate::ctrl::{ReconfigEvent, ReconfigKind, TransitionRecord};
use crate::sim::time::Duration;
use crate::transport::rel::{RelConfig, RelMode};
use crate::workload::openloop::{OpenLoop, OpenLoopConfig};
use crate::workload::scenario::Scenario;

use super::common::{ResultTable, Scale};

/// The p99 excursion around one transition, measured on bucketed
/// completion windows.
#[derive(Clone, Copy, Debug)]
pub struct DipSummary {
    /// p99 of completions *before* quiescing began, ns.
    pub pre_p99_ns: f64,
    /// Worst windowed p99 at/after quiesce begin, ns.
    pub peak_p99_ns: f64,
    /// `100 * (peak/pre - 1)`, floored at 0.
    pub depth_pct: f64,
    /// How long the windowed p99 stayed above `1.2 * pre`, µs
    /// (contiguous from the quiesce-begin window).
    pub dip_us: f64,
}

/// p99 of a sample slice (ps in, ps out).
fn p99(samples: &mut Vec<u64>) -> Option<u64> {
    if samples.is_empty() {
        return None;
    }
    samples.sort_unstable();
    let idx = (samples.len() * 99 / 100).min(samples.len() - 1);
    Some(samples[idx])
}

/// Bucket the control plane's completion timeline and summarize the
/// p99 excursion around `t`. `None` when there is no pre-transition
/// steady state to compare against.
pub fn dip_summary(timeline: &[(u64, u64)], t: &TransitionRecord) -> Option<DipSummary> {
    if timeline.len() < 2 {
        return None;
    }
    let begin = t.quiesce_start.ps();
    let mut pre: Vec<u64> =
        timeline.iter().filter(|&&(at, _)| at < begin).map(|&(_, l)| l).collect();
    let pre_p99 = p99(&mut pre)? as f64;
    let first = timeline[0].0;
    let last = timeline.last().expect("len >= 2").0;
    let span = (last - first).max(1);
    // >=1µs windows, at most 32 of them across the run (the same
    // bucketing fig_fabric uses for the failover goodput dip)
    let w = (span / 32).max(1_000_000);
    let n_buckets = (span / w + 1) as usize;
    let mut buckets: Vec<Vec<u64>> = vec![Vec::new(); n_buckets];
    for &(at, lat) in timeline {
        buckets[((at - first) / w) as usize].push(lat);
    }
    let first_post = ((begin.saturating_sub(first)) / w) as usize;
    let mut peak = 0u64;
    let mut dip_buckets = 0usize;
    let mut still_elevated = true;
    for (i, b) in buckets.iter_mut().enumerate().skip(first_post) {
        let Some(p) = p99(b) else {
            // an empty window right after quiesce begin *is* the stall
            if i > first_post && still_elevated {
                dip_buckets += 1;
            }
            continue;
        };
        peak = peak.max(p);
        if still_elevated && p as f64 > 1.2 * pre_p99 {
            dip_buckets += 1;
        } else if i > first_post {
            still_elevated = false;
        }
    }
    let peak = (peak as f64).max(pre_p99);
    Some(DipSummary {
        pre_p99_ns: pre_p99 / 1e3,
        peak_p99_ns: peak / 1e3,
        depth_pct: (100.0 * (peak / pre_p99 - 1.0)).max(0.0),
        dip_us: dip_buckets as f64 * w as f64 * 1e-6,
    })
}

/// One transition's row.
#[derive(Clone, Debug)]
pub struct ReconfigPoint {
    /// `reslice:4`, `cache:0`, `relmode:sr`, `drain:1`, `rejoin`.
    pub kind: String,
    /// Scripted fire time, µs.
    pub at_us: f64,
    pub quiesce_us: f64,
    pub stall_us: f64,
    pub parked: u64,
    pub moved_lines: u64,
    pub cache_victims: u64,
    pub skipped: bool,
    pub dip: Option<DipSummary>,
}

/// The figure: one scripted run, one row per transition.
#[derive(Clone, Debug)]
pub struct FigReconfig {
    pub scenario: String,
    pub completed: u64,
    pub points: Vec<ReconfigPoint>,
}

/// Run `events` against one open-loop cell and summarize each
/// transition's cost.
pub fn run_custom(
    cfg: OpenLoopConfig,
    scenario: &Scenario,
    slices: usize,
    events: Vec<ReconfigEvent>,
) -> FigReconfig {
    let r = OpenLoop::new(cfg, scenario, slices).with_reconfig(events).run();
    let rc = r.reconfig.expect("run_custom requires a non-empty script");
    let points = rc
        .transitions
        .iter()
        .map(|t| ReconfigPoint {
            kind: t.kind.label(),
            at_us: t.scheduled.ps() as f64 * 1e-6,
            quiesce_us: t.quiesce_us(),
            stall_us: t.stall_us(),
            parked: t.parked,
            moved_lines: t.moved_lines,
            cache_victims: t.cache_victims,
            skipped: t.skipped,
            dip: if t.skipped { None } else { dip_summary(&rc.timeline, t) },
        })
        .collect();
    FigReconfig { scenario: scenario.name.clone(), completed: r.completed, points }
}

pub fn ops_for(scale: Scale) -> u64 {
    match scale {
        Scale::Ci => 4_000,
        Scale::Default => 12_000,
        Scale::Paper => 48_000,
    }
}

/// The default transition script for a run of `ops` arrivals at
/// `rate`/s: all four transition families — re-slice 2→4, drain +
/// rejoin, a rel-mode swap, and a cache resize — spaced evenly across
/// the expected makespan. The `reconfig` CLI bench falls back to this
/// when no `--reconfig` script is given.
pub fn default_script(ops: u64, rate: f64) -> Vec<ReconfigEvent> {
    let t_us = (ops as f64 / rate) * 1e6;
    let at = |frac: f64| Duration::from_us((t_us * frac) as u64);
    vec![
        ReconfigEvent { at: at(0.15), kind: ReconfigKind::Reslice(4) },
        ReconfigEvent { at: at(0.30), kind: ReconfigKind::Drain(1) },
        ReconfigEvent { at: at(0.45), kind: ReconfigKind::Rejoin },
        ReconfigEvent { at: at(0.60), kind: ReconfigKind::RelSwap(RelMode::SelectiveRepeat) },
        ReconfigEvent { at: at(0.75), kind: ReconfigKind::CacheResize(0) },
    ]
}

/// The default figure: a cached 2-slice cell under streaming scan
/// traffic on a clean reliable link, walked through the
/// [`default_script`] transition sequence.
pub fn run(scale: Scale) -> FigReconfig {
    let ops = ops_for(scale);
    let rate = 6e6;
    let mut cfg = OpenLoopConfig { rate_per_s: rate, ops, home_cached: true, ..Default::default() };
    // reliable framing with zero injected faults: the rel-mode swap is
    // a real swap, and the link stays loss-free
    cfg.machine.rel = Some(RelConfig::from_ber(0.0, 0x5EED));
    let scenario = Scenario::preset("scan", 1 << 10, 0.99).expect("scan preset");
    run_custom(cfg, &scenario, 2, default_script(ops, rate))
}

pub fn render(f: &FigReconfig) -> ResultTable {
    let mut t = ResultTable::new(
        &format!(
            "Live reconfiguration: p99 dip depth and duration, scenario `{}` ({} ops)",
            f.scenario, f.completed
        ),
        &[
            "transition",
            "at_us",
            "quiesce_us",
            "stall_us",
            "parked",
            "moved_lines",
            "cache_victims",
            "pre_p99_ns",
            "peak_p99_ns",
            "dip_depth_pct",
            "dip_us",
        ],
    );
    for p in &f.points {
        let (pre, peak, depth, dip) = match &p.dip {
            Some(d) => (
                format!("{:.1}", d.pre_p99_ns),
                format!("{:.1}", d.peak_p99_ns),
                format!("{:.1}", d.depth_pct),
                format!("{:.2}", d.dip_us),
            ),
            None => {
                let s = if p.skipped { "skipped" } else { "-" }.to_string();
                (s.clone(), s.clone(), s.clone(), s)
            }
        };
        t.row(vec![
            p.kind.clone(),
            format!("{:.1}", p.at_us),
            format!("{:.2}", p.quiesce_us),
            format!("{:.2}", p.stall_us),
            p.parked.to_string(),
            p.moved_lines.to_string(),
            p.cache_victims.to_string(),
            pre,
            peak,
            depth,
            dip,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::time::Time;

    fn rec(begin_ps: u64) -> TransitionRecord {
        TransitionRecord::begun(
            ReconfigEvent { at: Duration(begin_ps), kind: ReconfigKind::Reslice(4) },
            Time(begin_ps),
        )
    }

    #[test]
    fn dip_summary_measures_a_synthetic_excursion() {
        // one completion per µs: 1000 ps latency in steady state, a
        // 50_000 ps spike over [100µs, 110µs)
        let mut tl: Vec<(u64, u64)> = Vec::new();
        for us in 0..200u64 {
            let lat = if (100..110).contains(&us) { 50_000 } else { 1_000 };
            tl.push((us * 1_000_000, lat));
        }
        let d = dip_summary(&tl, &rec(100 * 1_000_000)).expect("pre window exists");
        assert!((d.pre_p99_ns - 1.0).abs() < 1e-9, "steady p99 1ns, got {}", d.pre_p99_ns);
        assert!((d.peak_p99_ns - 50.0).abs() < 1e-9, "spike p99 50ns, got {}", d.peak_p99_ns);
        assert!(d.depth_pct > 1_000.0, "{}", d.depth_pct);
        assert!(d.dip_us >= 5.0 && d.dip_us <= 20.0, "{}", d.dip_us);
    }

    #[test]
    fn dip_summary_needs_a_pre_window() {
        let tl: Vec<(u64, u64)> = (0..50).map(|i| (i * 1_000_000, 1_000)).collect();
        assert!(dip_summary(&tl, &rec(0)).is_none(), "transition at t=0 has no baseline");
        assert!(dip_summary(&[], &rec(10)).is_none());
    }

    #[test]
    fn figure_runs_the_full_transition_family_end_to_end() {
        let mut cfg = OpenLoopConfig {
            rate_per_s: 6e6,
            ops: 2_500,
            home_cached: true,
            ..Default::default()
        };
        cfg.machine.rel = Some(RelConfig::from_ber(0.0, 0x5EED));
        let events = vec![
            ReconfigEvent { at: Duration::from_us(100), kind: ReconfigKind::Reslice(4) },
            ReconfigEvent { at: Duration::from_us(200), kind: ReconfigKind::Drain(1) },
            ReconfigEvent { at: Duration::from_us(280), kind: ReconfigKind::Rejoin },
            ReconfigEvent {
                at: Duration::from_us(340),
                kind: ReconfigKind::RelSwap(RelMode::SelectiveRepeat),
            },
        ];
        let sc = Scenario::preset("scan", 1 << 10, 0.99).expect("preset");
        let f = run_custom(cfg, &sc, 2, events);
        assert_eq!(f.completed, 2_500);
        assert_eq!(f.points.len(), 4);
        assert!(f.points.iter().all(|p| !p.skipped));
        assert!(f.points.iter().any(|p| p.parked > 0), "{:?}", f.points);
        assert!(
            f.points.iter().filter(|p| p.kind != "relmode:sr").all(|p| p.moved_lines > 0),
            "cached-directory handoffs move lines: {:?}",
            f.points
        );
        let table = render(&f);
        assert_eq!(table.rows.len(), 4);
        let md = table.to_markdown();
        assert!(md.contains("reslice:4") && md.contains("drain:1") && md.contains("rejoin"));
        // every executed transition has a measurable dip summary
        assert!(f.points.iter().all(|p| p.dip.is_some()), "{:?}", f.points);
    }
}
