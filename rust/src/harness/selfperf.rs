//! Selfperf: the simulator's *own* performance trajectory.
//!
//! Every other harness measures the modeled system; this one measures
//! the host — wall-clock simulated-operations/sec and events/sec on
//! five pinned configurations (fixed seeds, fixed op counts, fixed
//! machine shapes), so optimization work on the simulator has a
//! recorded baseline to regress against (`BENCH_6.json`).
//!
//! The baseline file carries a `calibrated` flag. A freshly seeded (or
//! placeholder) baseline has `calibrated: false`: `--check` then only
//! *warns*, because wall-clock numbers are machine-specific and a
//! baseline recorded on one host is noise on another. `--record` on the
//! reference machine writes `calibrated: true`, after which `--check`
//! fails hard on any config whose events/sec drops more than the
//! tolerance below baseline (and warns on improvements beyond it, a
//! hint to re-record).

use std::time::Instant;

use crate::agents::dram::MemStore;
use crate::fabric::{self, FabricConfig};
use crate::machine::{map, Machine, MachineConfig, Workload};
use crate::obs::Json;
use crate::proto::messages::{LineAddr, LINE_BYTES};
use crate::transport::{FaultConfig, FaultSpec, RelConfig, RelMode};
use crate::workload::openloop::{self, OpenLoopConfig};
use crate::workload::scenario::Scenario;

use super::common::{fmt_rate, ResultTable};

/// Baseline schema version (bump on incompatible changes).
pub const VERSION: u64 = 1;
/// Default relative tolerance of the regression gate.
pub const DEFAULT_TOLERANCE: f64 = 0.25;

/// Pinned workload sizes (full scale; tests shrink via [`run_with`]).
const STREAM_LINES: u64 = 100_000;
const STREAM_THREADS: usize = 8;
const OPENLOOP_OPS: u64 = 30_000;
const OPENLOOP_SLICES: usize = 2;

/// One measured configuration.
#[derive(Clone, Debug)]
pub struct SelfperfPoint {
    pub name: String,
    /// Simulated operations completed (deterministic given the seed).
    pub sim_ops: u64,
    /// Simulator events dispatched (deterministic given the seed).
    pub events: u64,
    /// Host wall-clock seconds for the measured run.
    pub wall_s: f64,
    pub ops_per_s: f64,
    pub events_per_s: f64,
}

fn measure(name: &str, mut run: impl FnMut() -> (u64, u64)) -> SelfperfPoint {
    let t0 = Instant::now();
    let (sim_ops, events) = run();
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    SelfperfPoint {
        name: name.to_string(),
        sim_ops,
        events,
        wall_s,
        ops_per_s: sim_ops as f64 / wall_s,
        events_per_s: events as f64 / wall_s,
    }
}

fn stream_machine(mk: impl Fn(MachineConfig, MemStore, MemStore) -> Machine, lines: u64) -> (u64, u64) {
    let cfg = MachineConfig::enzian_eci();
    let region_bytes = (lines as usize + 1024) * LINE_BYTES;
    let fpga = MemStore::new(map::TABLE_BASE, region_bytes);
    let cpu = MemStore::new(LineAddr(0), 1 << 20);
    let mut m = mk(cfg, fpga, cpu);
    m.set_workload(Workload::StreamRemote { lines }, STREAM_THREADS);
    let r = m.run();
    (lines, r.events)
}

/// The faulted selective-repeat transport configuration (the same
/// fault profile as the loss-transparency tests: BER 1e-4, 2% drops,
/// 2% reorders, seed 7).
fn faulted_sr_config(ops: u64) -> OpenLoopConfig {
    let spec = FaultSpec { ber: 1e-4, drop: 0.02, reorder: 0.02, burst_len: 1.0 };
    let mut rel = RelConfig::new(FaultConfig::new(spec, 7));
    rel.mode = RelMode::SelectiveRepeat;
    rel.adaptive_rto = true;
    let mut machine = MachineConfig::enzian_eci();
    machine.rel = Some(rel);
    OpenLoopConfig { ops, machine, ..Default::default() }
}

fn openloop_faulted(ops: u64) -> (u64, u64) {
    let cfg = faulted_sr_config(ops);
    let scenario = Scenario::preset("scan", 1 << 12, 0.99).expect("scan preset");
    let r = openloop::run(cfg, &scenario, OPENLOOP_SLICES);
    (r.completed, r.events)
}

/// The pinned two-node fabric configuration: uniform traffic over a
/// 2^10-line footprint per node, so roughly half of all fills take the
/// two-hop path — the simulator cost of the inter-node channels and the
/// routing layer is what this config tracks.
fn fabric_two_node(ops: u64) -> (u64, u64) {
    let cfg = FabricConfig {
        nodes: 2,
        ol: OpenLoopConfig { ops, ..Default::default() },
        ..Default::default()
    };
    let scenario = Scenario::preset("uniform", 1 << 10, 0.99).expect("uniform preset");
    let r = fabric::run(cfg, &scenario);
    (r.completed, r.events)
}

/// Run the five pinned configurations at `scale` (1.0 = full; tests use
/// a small fraction). Workload sizes scale; seeds and shapes do not.
pub fn run_with(scale: f64) -> Vec<SelfperfPoint> {
    let lines = ((STREAM_LINES as f64 * scale) as u64).max(256);
    let ops = ((OPENLOOP_OPS as f64 * scale) as u64).max(256);
    vec![
        measure("memory_node", || stream_machine(Machine::memory_node, lines)),
        measure("dcs", || {
            stream_machine(|c, f, m| Machine::dcs_node(c, OPENLOOP_SLICES, f, m), lines)
        }),
        measure("dcs_cached", || {
            stream_machine(|c, f, m| Machine::dcs_cached_node(c, OPENLOOP_SLICES, f, m), lines)
        }),
        measure("faulted_sr", || openloop_faulted(ops)),
        measure("fabric_2node", || fabric_two_node(ops)),
    ]
}

/// The full-scale trajectory measurement (`eci bench selfperf`).
pub fn run() -> Vec<SelfperfPoint> {
    run_with(1.0)
}

pub fn render(points: &[SelfperfPoint]) -> ResultTable {
    let mut t = ResultTable::new(
        "Selfperf: simulator host throughput (pinned configs, fixed seeds)",
        &["config", "sim ops", "events", "wall s", "ops/s", "events/s"],
    );
    for p in points {
        t.row(vec![
            p.name.clone(),
            p.sim_ops.to_string(),
            p.events.to_string(),
            format!("{:.3}", p.wall_s),
            fmt_rate(p.ops_per_s),
            fmt_rate(p.events_per_s),
        ]);
    }
    t
}

/// Serialize a measurement as a baseline file body.
pub fn to_json(points: &[SelfperfPoint], calibrated: bool) -> Json {
    let configs = points
        .iter()
        .map(|p| {
            Json::Obj(vec![
                ("name".into(), Json::s(&p.name)),
                ("sim_ops".into(), Json::u(p.sim_ops)),
                ("events".into(), Json::u(p.events)),
                ("ops_per_s".into(), Json::f(p.ops_per_s)),
                ("events_per_s".into(), Json::f(p.events_per_s)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("version".into(), Json::u(VERSION)),
        ("calibrated".into(), Json::Bool(calibrated)),
        ("tolerance".into(), Json::f(DEFAULT_TOLERANCE)),
        ("configs".into(), Json::Arr(configs)),
    ])
}

/// Outcome of a `--check` run.
#[derive(Debug)]
pub struct CheckReport {
    pub pass: bool,
    pub lines: Vec<String>,
}

/// Compare a measurement against a baseline. Regressions (events/sec
/// below `1 - tolerance` of baseline) fail only when the baseline is
/// calibrated; improvements beyond `1 + tolerance` and uncalibrated
/// baselines produce warnings.
pub fn check(points: &[SelfperfPoint], baseline: &Json, tolerance: Option<f64>) -> CheckReport {
    let calibrated = baseline.get("calibrated").and_then(|v| v.as_bool()).unwrap_or(false);
    let tol = tolerance
        .or_else(|| baseline.get("tolerance").and_then(|v| v.as_f64()))
        .unwrap_or(DEFAULT_TOLERANCE);
    let empty = Vec::new();
    let configs = baseline.get("configs").and_then(|v| v.as_arr()).unwrap_or(&empty);
    let mut lines = Vec::new();
    let mut pass = true;
    if !calibrated {
        lines.push(
            "baseline is uncalibrated (placeholder): reporting only — record with \
             `eci bench selfperf --record <path>` on the reference machine"
                .to_string(),
        );
    }
    for p in points {
        let base = configs
            .iter()
            .find(|c| c.get("name").and_then(|v| v.as_str()) == Some(p.name.as_str()));
        let Some(base) = base else {
            lines.push(format!("{}: no baseline entry (new config?)", p.name));
            continue;
        };
        let base_eps = base.get("events_per_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
        if base_eps <= 0.0 {
            lines.push(format!("{}: baseline has no rate recorded", p.name));
            continue;
        }
        let ratio = p.events_per_s / base_eps;
        if ratio < 1.0 - tol {
            if calibrated {
                pass = false;
                lines.push(format!(
                    "{}: REGRESSION {:.2}x baseline events/s ({} vs {})",
                    p.name,
                    ratio,
                    fmt_rate(p.events_per_s),
                    fmt_rate(base_eps)
                ));
            } else {
                lines.push(format!(
                    "{}: {:.2}x baseline events/s (uncalibrated — not failing)",
                    p.name, ratio
                ));
            }
        } else if ratio > 1.0 + tol {
            lines.push(format!(
                "{}: improvement {:.2}x baseline events/s — consider re-recording",
                p.name, ratio
            ));
        } else {
            lines.push(format!("{}: ok ({:.2}x baseline events/s)", p.name, ratio));
        }
    }
    CheckReport { pass, lines }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_pinned_configs_measure_and_serialize() {
        let points = run_with(0.01);
        assert_eq!(points.len(), 5);
        let names: Vec<&str> = points.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, ["memory_node", "dcs", "dcs_cached", "faulted_sr", "fabric_2node"]);
        for p in &points {
            assert!(p.sim_ops > 0, "{}: no ops", p.name);
            assert!(p.events > 0, "{}: no events", p.name);
            assert!(p.ops_per_s > 0.0 && p.events_per_s > 0.0, "{}: no rate", p.name);
        }
        let j = to_json(&points, false);
        let back = Json::parse(&j.pretty()).unwrap();
        assert_eq!(back.get("version").and_then(|v| v.as_u64()), Some(VERSION));
        assert_eq!(back.get("calibrated").and_then(|v| v.as_bool()), Some(false));
        assert_eq!(back.get("configs").and_then(|v| v.as_arr()).map(|a| a.len()), Some(5));
        let md = render(&points).to_markdown();
        assert!(md.contains("events/s") && md.contains("fabric_2node"));
    }

    #[test]
    fn check_gates_on_calibration_and_tolerance() {
        let points = run_with(0.01);
        // self-recorded calibrated baseline: everything within band
        let base = to_json(&points, true);
        let r = check(&points, &base, Some(0.25));
        assert!(r.pass, "self-check must pass: {:?}", r.lines);
        // a calibrated baseline 10x faster than us: hard failure
        let mut fast = points.clone();
        for p in &mut fast {
            p.events_per_s *= 10.0;
        }
        let r = check(&points, &to_json(&fast, true), Some(0.25));
        assert!(!r.pass, "10x regression must fail");
        assert!(r.lines.iter().any(|l| l.contains("REGRESSION")));
        // the same gap against an *uncalibrated* baseline: warn, pass
        let r = check(&points, &to_json(&fast, false), Some(0.25));
        assert!(r.pass, "uncalibrated baseline must not fail: {:?}", r.lines);
        assert!(r.lines.iter().any(|l| l.contains("uncalibrated")));
        // an improvement beyond band: warn, pass
        let mut slow = points.clone();
        for p in &mut slow {
            p.events_per_s /= 10.0;
        }
        let r = check(&points, &to_json(&slow, true), Some(0.25));
        assert!(r.pass);
        assert!(r.lines.iter().any(|l| l.contains("re-recording")));
    }
}
