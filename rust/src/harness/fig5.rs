//! Figure 5: SELECT throughput vs. selectivity and thread count, CPU and
//! FPGA implementations (paper §5.4).
//!
//! Shape criteria (DESIGN.md §4): CPU scan rate flat in selectivity and
//! DRAM-bandwidth-bound; FPGA scan DRAM-bound at low selectivity once
//! enough threads keep the pipeline full, interconnect-bound at 100%;
//! results/s *inversion* at high selectivity (CPU wins on local-DRAM
//! bandwidth when everything is returned).

use crate::agents::dram::MemStore;
use crate::anyhow;
use crate::machine::{map, FpgaApp, Machine, MachineConfig, Workload};
use crate::memctl::{FifoServer, ScanTiming};
use crate::operators::select::{cpu_select_scan, fpga_select_scan};
use crate::operators::table::{build_table, select_params, TableSpec};
use crate::proto::messages::{LineAddr, LINE_BYTES};
use crate::runtime::Runtime;
use crate::sim::time::Duration;

use super::common::{fmt_rate, ResultTable, Scale};

pub const PAPER_ROWS: u64 = 5_120_000;
/// Compute cycles per row for the CPU scalar predicate scan (two f32
/// compares + branch + loop on a dual-issue in-order core).
pub const CPU_CYCLES_PER_ROW: u64 = 10;
/// Extra cycles to materialize a matching row into the result buffer.
pub const CPU_MATCH_EXTRA: u64 = 32;
/// SELECT comparator engines on the FPGA (cheap; the scan is DRAM-bound).
pub const FPGA_ENGINES: u32 = 8;

#[derive(Clone, Debug)]
pub struct FigPoint {
    pub selectivity: f64,
    pub threads: usize,
    pub scan_rows_per_s: f64,
    pub results_per_s: f64,
    pub dram_gbps: f64,
}

/// Precomputed per-selectivity scan state, reusable across thread counts
/// (PERF: the functional scan through the XLA kernel is identical for
/// every thread count; scanning once per selectivity instead of once per
/// point cut harness wall-clock ~7x — DESIGN.md §Perf).
pub struct PreparedScan {
    pub rows: u64,
    pub selectivity: f64,
    store: MemStore,
    matches: Vec<u64>,
    x: f32,
    y: f32,
}

pub fn prepare(rt: &mut Runtime, rows: u64, selectivity: f64) -> anyhow::Result<PreparedScan> {
    let spec = TableSpec::new(rows, selectivity);
    let mut store = MemStore::new(map::TABLE_BASE, rows as usize * LINE_BYTES);
    build_table(&spec, &mut store);
    let (x, y) = select_params(selectivity);
    // functional scan through the AOT XLA kernel, once
    let matches = fpga_select_scan(rt, &store, map::TABLE_BASE, rows, x, y)?;
    Ok(PreparedScan { rows, selectivity, store, matches, x, y })
}

/// One FPGA-offload run over a prepared scan.
pub fn run_fpga_prepared(p: &PreparedScan, threads: usize) -> FigPoint {
    let rows = p.rows;
    let payloads: Vec<_> = p
        .matches
        .iter()
        .map(|&i| Box::new(p.store.read_line(LineAddr(map::TABLE_BASE.0 + i))))
        .collect();
    let fifo = FifoServer::new(
        rows,
        p.matches.clone(),
        payloads,
        |_| 1, // one comparator cycle per row per engine
        ScanTiming::enzian(FPGA_ENGINES),
        64 << 10,
    );
    let total_results = fifo.total_results() as u64;

    let cfg = MachineConfig::enzian_eci();
    let cpu_mem = MemStore::new(LineAddr(0), 1 << 20);
    let mut m = Machine::new(cfg, FpgaApp::Fifo(fifo), p.store.clone(), cpu_mem);
    m.config_block.set_select_params(p.x, p.y);
    m.set_workload(Workload::FifoConsume { think: Duration::from_ns(5) }, threads);
    let r = m.run();
    assert_eq!(r.results, total_results, "every result must be delivered");
    FigPoint {
        selectivity: p.selectivity,
        threads,
        scan_rows_per_s: rows as f64 / r.sim_time.as_secs(),
        results_per_s: r.results_per_s(),
        dram_gbps: rows as f64 * 128.0 / r.sim_time.as_secs() / 1e9,
    }
}

/// One FPGA-offload run (standalone).
pub fn run_fpga(
    rt: &mut Runtime,
    rows: u64,
    selectivity: f64,
    threads: usize,
) -> anyhow::Result<FigPoint> {
    Ok(run_fpga_prepared(&prepare(rt, rows, selectivity)?, threads))
}

/// One CPU-only run (data in CPU DRAM).
pub fn run_cpu(rows: u64, selectivity: f64, threads: usize) -> FigPoint {
    let spec = TableSpec::new(rows, selectivity);
    let mut store = MemStore::new(LineAddr(0), rows as usize * LINE_BYTES);
    build_table(&spec, &mut store);
    let (x, y) = select_params(selectivity);
    let matches = cpu_select_scan(&store, LineAddr(0), rows, x, y);
    let mut mask = vec![false; rows as usize];
    for &i in &matches {
        mask[i as usize] = true;
    }
    let cfg = MachineConfig::enzian_eci();
    let fpga_mem = MemStore::new(map::TABLE_BASE, 1 << 20);
    let mut m = Machine::memory_node(cfg, fpga_mem, store);
    m.set_workload(
        Workload::LocalScan {
            rows,
            cycles_per_row: CPU_CYCLES_PER_ROW,
            match_extra: CPU_MATCH_EXTRA,
            matches: mask,
        },
        threads,
    );
    let r = m.run();
    FigPoint {
        selectivity,
        threads,
        scan_rows_per_s: r.rows_per_s(),
        results_per_s: r.results as f64 / r.sim_time.as_secs(),
        dram_gbps: r.rows_per_s() * 128.0 / 1e9,
    }
}

pub struct Fig5 {
    pub fpga: Vec<FigPoint>,
    pub cpu: Vec<FigPoint>,
}

pub fn run(rt: &mut Runtime, scale: Scale) -> anyhow::Result<Fig5> {
    let rows = scale.rows(PAPER_ROWS);
    let mut fpga = Vec::new();
    let mut cpu = Vec::new();
    for &sel in &[0.01, 0.10, 1.00] {
        let prepared = prepare(rt, rows, sel)?;
        for &t in &scale.threads() {
            fpga.push(run_fpga_prepared(&prepared, t));
            cpu.push(run_cpu(rows, sel, t));
        }
    }
    Ok(Fig5 { fpga, cpu })
}

pub fn render(f: &Fig5) -> ResultTable {
    let mut t = ResultTable::new(
        "Figure 5: SELECT throughput vs. selectivity and thread count",
        &["impl", "selectivity", "threads", "scan rows/s", "results/s", "scan GB/s"],
    );
    for (name, pts) in [("FPGA", &f.fpga), ("CPU", &f.cpu)] {
        for p in pts.iter() {
            t.row(vec![
                name.into(),
                format!("{:.0}%", p.selectivity * 100.0),
                p.threads.to_string(),
                fmt_rate(p.scan_rows_per_s),
                fmt_rate(p.results_per_s),
                format!("{:.1}", p.dram_gbps),
            ]);
        }
    }
    t
}
