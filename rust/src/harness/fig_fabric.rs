//! Fabric scale-out: aggregate goodput and tail latency vs node count,
//! with home migration on/off.
//!
//! Each fabric node is a full open-loop unit cell (its own directory
//! slices, FPGA DRAM, KVS pool, framed links); the global interleave
//! scatters every node's traffic window across all homes, so at N nodes
//! roughly (N−1)/N of fills take the two-hop remote path. The sweep
//! holds the *per-node* offered rate at a node-saturating point and
//! grows N: aggregate goodput must scale with the node count (each node
//! adds directory capacity), while the latency distribution absorbs the
//! extra fabric hop. The migration rows re-run each point with
//! threshold-based home migration enabled — hot lines move to their
//! dominant talker, converting two-hop fills into local ones.
//!
//! Shape criteria (asserted at CI scale below): 2-node aggregate
//! goodput strictly exceeds 1-node under node-saturating load, and
//! migration at N≥2 commits moves and cuts the remote-fill share.

use crate::fabric::{self, FabricConfig};
use crate::workload::openloop::OpenLoopConfig;
use crate::workload::scenario::Scenario;

use super::common::{fmt_rate, ResultTable, Scale};
use super::fig_loadcurve::base_rate;

/// Fabric-wide arrivals per sweep point at each scale.
pub fn ops_for(scale: Scale) -> u64 {
    match scale {
        Scale::Ci => 1_600,
        Scale::Default => 8_000,
        Scale::Paper => 32_000,
    }
}

/// Per-node scenario footprint (base lines for [`Scenario::preset`]).
pub fn footprint_for(scale: Scale) -> u64 {
    match scale {
        Scale::Ci => 1 << 10,
        Scale::Default => 1 << 12,
        Scale::Paper => 1 << 14,
    }
}

/// Node counts swept by default.
pub fn node_sweep(scale: Scale) -> Vec<u8> {
    match scale {
        Scale::Ci => vec![1, 2],
        _ => vec![1, 2, 4],
    }
}

/// A per-node offered rate that saturates one node's two default
/// directory slices (ops cost ~2 slice messages each, so 2-slice
/// capacity ≈ 2 × [`base_rate`]); holding it per node makes aggregate
/// goodput a direct read of how capacity scales with N.
pub fn saturating_rate(cfg: &OpenLoopConfig) -> f64 {
    3.2 * base_rate(cfg.machine.home_proc)
}

/// One (node count, migration mode) sweep point.
#[derive(Clone, Debug)]
pub struct FabricPoint {
    pub nodes: usize,
    pub migrate: bool,
    pub offered_per_s: f64,
    pub delivered_per_s: f64,
    pub completed: u64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub p999_ns: f64,
    /// Share of coherence fills that took the two-hop remote path.
    pub remote_fill_frac: f64,
    /// Committed home migrations.
    pub migrations: u64,
    /// Lines living away from their natural interleave home at the end.
    pub moved_lines: usize,
    /// p99 of the per-frame inter-node hop latency (0 at one node).
    pub hop_p99_ns: f64,
    pub events: u64,
}

pub struct FigFabric {
    pub scenario: String,
    pub points: Vec<FabricPoint>,
}

/// Run one fabric configuration and flatten its report into a row.
pub fn run_point(cfg: FabricConfig, scenario: &Scenario) -> FabricPoint {
    let r = fabric::run(cfg, scenario);
    FabricPoint {
        nodes: r.nodes,
        migrate: r.migrate,
        offered_per_s: r.offered_per_s,
        delivered_per_s: r.delivered_per_s,
        completed: r.completed,
        p50_ns: r.p50_ns(),
        p99_ns: r.p99_ns(),
        p999_ns: r.p999_ns(),
        remote_fill_frac: r.remote_fill_frac(),
        migrations: r.migrations,
        moved_lines: r.moved_lines,
        hop_p99_ns: r.hop_p99_ns(),
        events: r.events,
    }
}

/// Full figure: every node count at each requested migration setting,
/// same scenario and per-node rate throughout.
pub fn run_custom(
    base: FabricConfig,
    scenario: &Scenario,
    nodes: &[u8],
    modes: &[bool],
) -> FigFabric {
    let mut points = Vec::with_capacity(nodes.len() * modes.len());
    for &migrate in modes {
        for &n in nodes {
            let cfg = FabricConfig { nodes: n, migrate, ..base };
            points.push(run_point(cfg, scenario));
        }
    }
    FigFabric { scenario: scenario.name.clone(), points }
}

/// The default figure: hot-kvs traffic (Zipf-hot lines make migration
/// worthwhile) at a node-saturating per-node rate.
pub fn run(scale: Scale) -> FigFabric {
    let ol = OpenLoopConfig { ops: ops_for(scale), ..Default::default() };
    let ol = OpenLoopConfig { rate_per_s: saturating_rate(&ol), ..ol };
    let base = FabricConfig { ol, ..Default::default() };
    let scenario =
        Scenario::preset("hot-kvs", footprint_for(scale), 0.99).expect("hot-kvs preset");
    run_custom(base, &scenario, &node_sweep(scale), &[false, true])
}

pub fn render(f: &FigFabric) -> ResultTable {
    let mut t = ResultTable::new(
        &format!(
            "Fabric scale-out: goodput and tails vs node count, scenario `{}`",
            f.scenario
        ),
        &[
            "nodes",
            "migrate",
            "offered/s",
            "goodput/s",
            "p50 ns",
            "p99 ns",
            "p999 ns",
            "remote fill %",
            "migrations",
            "moved lines",
            "hop p99 ns",
        ],
    );
    for p in &f.points {
        t.row(vec![
            p.nodes.to_string(),
            if p.migrate { "on".into() } else { "off".into() },
            fmt_rate(p.offered_per_s),
            fmt_rate(p.delivered_per_s),
            format!("{:.0}", p.p50_ns),
            format!("{:.0}", p.p99_ns),
            format!("{:.0}", p.p999_ns),
            format!("{:.1}", 100.0 * p.remote_fill_frac),
            p.migrations.to_string(),
            p.moved_lines.to_string(),
            format!("{:.0}", p.hop_p99_ns),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ci_fig() -> FigFabric {
        run(Scale::Ci)
    }

    /// Acceptance: under node-saturating load, 2-node aggregate goodput
    /// strictly exceeds 1-node (each node brings its own directory).
    #[test]
    fn aggregate_goodput_scales_with_nodes() {
        let f = ci_fig();
        let g = |nodes: usize, migrate: bool| {
            f.points
                .iter()
                .find(|p| p.nodes == nodes && p.migrate == migrate)
                .unwrap_or_else(|| panic!("missing point ({nodes}, {migrate})"))
        };
        let one = g(1, false);
        let two = g(2, false);
        assert_eq!(one.completed, ops_for(Scale::Ci));
        assert_eq!(two.completed, ops_for(Scale::Ci));
        assert!(
            two.delivered_per_s > 1.3 * one.delivered_per_s,
            "2-node goodput {} must scale past 1-node {}",
            two.delivered_per_s,
            one.delivered_per_s
        );
        // a 1-node fabric has no inter-node hops; a 2-node one must
        assert_eq!(one.remote_fill_frac, 0.0);
        assert!(two.remote_fill_frac > 0.25, "interleave must scatter homes");
        assert!(two.hop_p99_ns > 0.0);
    }

    /// Acceptance: migration commits moves at N=2 and cuts the
    /// remote-fill share vs the migration-off row.
    #[test]
    fn migration_cuts_remote_fill_share() {
        let f = ci_fig();
        let g = |migrate: bool| {
            f.points.iter().find(|p| p.nodes == 2 && p.migrate == migrate).expect("2-node rows")
        };
        let off = g(false);
        let on = g(true);
        assert_eq!(off.migrations, 0);
        assert!(on.migrations > 0, "hot remote-homed lines must move");
        assert!(on.moved_lines > 0);
        assert!(
            on.remote_fill_frac < off.remote_fill_frac,
            "migration must cut the remote-fill share: {} vs {}",
            on.remote_fill_frac,
            off.remote_fill_frac
        );
    }

    #[test]
    fn render_has_one_row_per_point() {
        let f = ci_fig();
        let t = render(&f);
        assert_eq!(t.rows.len(), f.points.len());
        assert_eq!(f.points.len(), 2 * node_sweep(Scale::Ci).len());
        let md = t.to_markdown();
        assert!(md.contains("remote fill %") && md.contains("hop p99 ns"));
    }
}
