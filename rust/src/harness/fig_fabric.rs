//! Fabric scale-out: aggregate goodput and tail latency vs node count,
//! with home migration on/off.
//!
//! Each fabric node is a full open-loop unit cell (its own directory
//! slices, FPGA DRAM, KVS pool, framed links); the global interleave
//! scatters every node's traffic window across all homes, so at N nodes
//! roughly (N−1)/N of fills take the two-hop remote path. The sweep
//! holds the *per-node* offered rate at a node-saturating point and
//! grows N: aggregate goodput must scale with the node count (each node
//! adds directory capacity), while the latency distribution absorbs the
//! extra fabric hop. The migration rows re-run each point with
//! threshold-based home migration enabled — hot lines move to their
//! dominant talker, converting two-hop fills into local ones.
//!
//! Shape criteria (asserted at CI scale below): 2-node aggregate
//! goodput strictly exceeds 1-node under node-saturating load, and
//! migration at N≥2 commits moves and cuts the remote-fill share.

use crate::fabric::{self, FabricConfig, KillReport};
use crate::sim::time::Duration;
use crate::workload::openloop::OpenLoopConfig;
use crate::workload::scenario::Scenario;

use super::common::{fmt_rate, ResultTable, Scale};
use super::fig_loadcurve::base_rate;

/// Fabric-wide arrivals per sweep point at each scale.
pub fn ops_for(scale: Scale) -> u64 {
    match scale {
        Scale::Ci => 1_600,
        Scale::Default => 8_000,
        Scale::Paper => 32_000,
    }
}

/// Per-node scenario footprint (base lines for [`Scenario::preset`]).
pub fn footprint_for(scale: Scale) -> u64 {
    match scale {
        Scale::Ci => 1 << 10,
        Scale::Default => 1 << 12,
        Scale::Paper => 1 << 14,
    }
}

/// Node counts swept by default.
pub fn node_sweep(scale: Scale) -> Vec<u8> {
    match scale {
        Scale::Ci => vec![1, 2],
        _ => vec![1, 2, 4],
    }
}

/// A per-node offered rate that saturates one node's two default
/// directory slices (ops cost ~2 slice messages each, so 2-slice
/// capacity ≈ 2 × [`base_rate`]); holding it per node makes aggregate
/// goodput a direct read of how capacity scales with N.
pub fn saturating_rate(cfg: &OpenLoopConfig) -> f64 {
    3.2 * base_rate(cfg.machine.home_proc)
}

/// Arrivals needed so a kill scheduled at `at` lands *mid-run* rather
/// than after the last completion: the configured sweep ops, or enough
/// arrivals to keep the fabric busy ~60% past the kill time, whichever
/// is larger. Without this, the default CI sweep (~20µs of traffic)
/// would finish long before a `--kill 1@200` ever fired.
pub fn ops_covering_kill(base_ops: u64, per_node_rate: f64, nodes: u8, at: Duration) -> u64 {
    let span_s = at.ps() as f64 * 1e-12;
    let needed = (per_node_rate * nodes as f64 * span_s * 1.6).ceil() as u64;
    base_ops.max(needed)
}

/// Post-failure goodput trajectory distilled from the completion
/// timeline of a killed run: how deep the dip went relative to the
/// pre-kill steady rate, and how long after the kill the fabric climbed
/// back to its survivor steady state.
#[derive(Clone, Debug)]
pub struct FailoverSummary {
    pub node: u8,
    pub killed_us: f64,
    /// Kill-to-declaration latency, µs.
    pub detect_us: Option<f64>,
    pub rehomed_lines: u64,
    pub replayed: u64,
    pub reclaimed_epochs: u64,
    pub abandoned_ops: u64,
    /// Worst post-kill goodput bucket vs the pre-kill steady rate, %.
    /// `None` when the timeline is too short to bucket on either side.
    pub dip_depth_pct: Option<f64>,
    /// Time from the kill until a goodput bucket regained >= 90% of the
    /// survivor steady rate, µs.
    pub recovery_us: Option<f64>,
}

/// Bucket a killed run's completion timestamps into goodput windows and
/// read off the dip depth and recovery point. Returns `None` when the
/// node was never actually killed (the run finished first).
pub fn failover_summary(k: &KillReport) -> Option<FailoverSummary> {
    let killed_ps = k.killed_at?.ps();
    let mut out = FailoverSummary {
        node: k.node,
        killed_us: killed_ps as f64 * 1e-6,
        detect_us: k.detect_latency().map(|d| d.ps() as f64 * 1e-6),
        rehomed_lines: k.rehomed_lines,
        replayed: k.replayed,
        reclaimed_epochs: k.reclaimed_epochs,
        abandoned_ops: k.abandoned_ops,
        dip_depth_pct: None,
        recovery_us: None,
    };
    let ps = &k.completion_ps;
    if ps.len() < 2 {
        return Some(out);
    }
    let first = ps[0];
    let last = *ps.last().expect("non-empty");
    let span = (last - first).max(1);
    // >=1µs windows, at most 32 of them across the run
    let w = (span / 32).max(1_000_000);
    let n_buckets = (span / w + 1) as usize;
    let mut counts = vec![0u64; n_buckets];
    for &t in ps {
        counts[((t - first) / w) as usize] += 1;
    }
    let rate_of = |c: u64| c as f64 / (w as f64 * 1e-12);
    // the final bucket is partial width; keep it out of the statistics
    let full = counts.len().saturating_sub(1);
    let pre: Vec<f64> = (0..full)
        .filter(|&i| first + (i as u64 + 1) * w <= killed_ps)
        .map(|i| rate_of(counts[i]))
        .collect();
    let post: Vec<(usize, f64)> = (0..full)
        .filter(|&i| first + i as u64 * w >= killed_ps)
        .map(|i| (i, rate_of(counts[i])))
        .collect();
    if pre.is_empty() || post.is_empty() {
        return Some(out);
    }
    let pre_steady = pre.iter().sum::<f64>() / pre.len() as f64;
    let dip = post.iter().map(|&(_, r)| r).fold(f64::INFINITY, f64::min);
    if pre_steady > 0.0 {
        out.dip_depth_pct = Some((100.0 * (1.0 - dip / pre_steady)).clamp(0.0, 100.0));
    }
    // survivor steady state: the back half of the post-kill buckets
    let tail = &post[post.len() / 2..];
    let post_steady = tail.iter().map(|&(_, r)| r).sum::<f64>() / tail.len() as f64;
    if post_steady > 0.0 {
        if let Some(&(i, _)) = post.iter().find(|&&(_, r)| r >= 0.9 * post_steady) {
            let bucket_end = first + (i as u64 + 1) * w;
            out.recovery_us = Some(bucket_end.saturating_sub(killed_ps) as f64 * 1e-6);
        }
    }
    Some(out)
}

/// One (node count, migration mode) sweep point.
#[derive(Clone, Debug)]
pub struct FabricPoint {
    pub nodes: usize,
    pub migrate: bool,
    pub offered_per_s: f64,
    pub delivered_per_s: f64,
    pub completed: u64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub p999_ns: f64,
    /// Share of coherence fills that took the two-hop remote path.
    pub remote_fill_frac: f64,
    /// Committed home migrations.
    pub migrations: u64,
    /// Lines living away from their natural interleave home at the end.
    pub moved_lines: usize,
    /// p99 of the per-frame inter-node hop latency (0 at one node).
    pub hop_p99_ns: f64,
    pub events: u64,
    /// Present iff the point ran with a scripted node kill that fired.
    pub failover: Option<FailoverSummary>,
}

pub struct FigFabric {
    pub scenario: String,
    pub points: Vec<FabricPoint>,
}

/// Run one fabric configuration and flatten its report into a row.
pub fn run_point(cfg: FabricConfig, scenario: &Scenario) -> FabricPoint {
    let r = fabric::run(cfg, scenario);
    let failover = r.kill.as_ref().and_then(failover_summary);
    FabricPoint {
        nodes: r.nodes,
        migrate: r.migrate,
        offered_per_s: r.offered_per_s,
        delivered_per_s: r.delivered_per_s,
        completed: r.completed,
        p50_ns: r.p50_ns(),
        p99_ns: r.p99_ns(),
        p999_ns: r.p999_ns(),
        remote_fill_frac: r.remote_fill_frac(),
        migrations: r.migrations,
        moved_lines: r.moved_lines,
        hop_p99_ns: r.hop_p99_ns(),
        events: r.events,
        failover,
    }
}

/// Full figure: every node count at each requested migration setting,
/// same scenario and per-node rate throughout.
pub fn run_custom(
    base: FabricConfig,
    scenario: &Scenario,
    nodes: &[u8],
    modes: &[bool],
) -> FigFabric {
    let mut points = Vec::with_capacity(nodes.len() * modes.len());
    for &migrate in modes {
        for &n in nodes {
            let mut cfg = FabricConfig { nodes: n, migrate, ..base };
            if let Some(k) = cfg.kill {
                // a kill only makes sense with survivors to fail over to;
                // sweep points too small for it run unkilled
                if n < 2 || k.node >= n {
                    cfg.kill = None;
                } else {
                    cfg.ol.ops = ops_covering_kill(cfg.ol.ops, cfg.ol.rate_per_s, n, k.at);
                }
            }
            points.push(run_point(cfg, scenario));
        }
    }
    FigFabric { scenario: scenario.name.clone(), points }
}

/// The default figure: hot-kvs traffic (Zipf-hot lines make migration
/// worthwhile) at a node-saturating per-node rate.
pub fn run(scale: Scale) -> FigFabric {
    let ol = OpenLoopConfig { ops: ops_for(scale), ..Default::default() };
    let ol = OpenLoopConfig { rate_per_s: saturating_rate(&ol), ..ol };
    let base = FabricConfig { ol, ..Default::default() };
    let scenario =
        Scenario::preset("hot-kvs", footprint_for(scale), 0.99).expect("hot-kvs preset");
    run_custom(base, &scenario, &node_sweep(scale), &[false, true])
}

pub fn render(f: &FigFabric) -> ResultTable {
    let mut t = ResultTable::new(
        &format!(
            "Fabric scale-out: goodput and tails vs node count, scenario `{}`",
            f.scenario
        ),
        &[
            "nodes",
            "migrate",
            "offered/s",
            "goodput/s",
            "p50 ns",
            "p99 ns",
            "p999 ns",
            "remote fill %",
            "migrations",
            "moved lines",
            "hop p99 ns",
        ],
    );
    for p in &f.points {
        t.row(vec![
            p.nodes.to_string(),
            if p.migrate { "on".into() } else { "off".into() },
            fmt_rate(p.offered_per_s),
            fmt_rate(p.delivered_per_s),
            format!("{:.0}", p.p50_ns),
            format!("{:.0}", p.p99_ns),
            format!("{:.0}", p.p999_ns),
            format!("{:.1}", 100.0 * p.remote_fill_frac),
            p.migrations.to_string(),
            p.moved_lines.to_string(),
            format!("{:.0}", p.hop_p99_ns),
        ]);
    }
    t
}

/// Companion table for killed runs: one row per sweep point whose
/// scripted kill actually fired, with the dip-depth/recovery readout
/// the ISSUE's `--kill` figure asks for. `None` when no point was
/// killed (the common, unkilled sweep).
pub fn render_failover(f: &FigFabric) -> Option<ResultTable> {
    let killed: Vec<(&FabricPoint, &FailoverSummary)> =
        f.points.iter().filter_map(|p| p.failover.as_ref().map(|s| (p, s))).collect();
    if killed.is_empty() {
        return None;
    }
    let mut t = ResultTable::new(
        &format!("Whole-node failover: goodput dip and recovery, scenario `{}`", f.scenario),
        &[
            "nodes",
            "migrate",
            "killed node",
            "killed @ us",
            "detect us",
            "dip depth %",
            "recovery us",
            "rehomed",
            "replayed",
            "reclaimed",
            "abandoned",
        ],
    );
    let opt = |v: Option<f64>| v.map_or("-".to_string(), |x| format!("{x:.1}"));
    for (p, s) in killed {
        t.row(vec![
            p.nodes.to_string(),
            if p.migrate { "on".into() } else { "off".into() },
            s.node.to_string(),
            format!("{:.1}", s.killed_us),
            opt(s.detect_us),
            opt(s.dip_depth_pct),
            opt(s.recovery_us),
            s.rehomed_lines.to_string(),
            s.replayed.to_string(),
            s.reclaimed_epochs.to_string(),
            s.abandoned_ops.to_string(),
        ]);
    }
    Some(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::KillSpec;

    fn ci_fig() -> FigFabric {
        run(Scale::Ci)
    }

    /// Acceptance: under node-saturating load, 2-node aggregate goodput
    /// strictly exceeds 1-node (each node brings its own directory).
    #[test]
    fn aggregate_goodput_scales_with_nodes() {
        let f = ci_fig();
        let g = |nodes: usize, migrate: bool| {
            f.points
                .iter()
                .find(|p| p.nodes == nodes && p.migrate == migrate)
                .unwrap_or_else(|| panic!("missing point ({nodes}, {migrate})"))
        };
        let one = g(1, false);
        let two = g(2, false);
        assert_eq!(one.completed, ops_for(Scale::Ci));
        assert_eq!(two.completed, ops_for(Scale::Ci));
        assert!(
            two.delivered_per_s > 1.3 * one.delivered_per_s,
            "2-node goodput {} must scale past 1-node {}",
            two.delivered_per_s,
            one.delivered_per_s
        );
        // a 1-node fabric has no inter-node hops; a 2-node one must
        assert_eq!(one.remote_fill_frac, 0.0);
        assert!(two.remote_fill_frac > 0.25, "interleave must scatter homes");
        assert!(two.hop_p99_ns > 0.0);
    }

    /// Acceptance: migration commits moves at N=2 and cuts the
    /// remote-fill share vs the migration-off row.
    #[test]
    fn migration_cuts_remote_fill_share() {
        let f = ci_fig();
        let g = |migrate: bool| {
            f.points.iter().find(|p| p.nodes == 2 && p.migrate == migrate).expect("2-node rows")
        };
        let off = g(false);
        let on = g(true);
        assert_eq!(off.migrations, 0);
        assert!(on.migrations > 0, "hot remote-homed lines must move");
        assert!(on.moved_lines > 0);
        assert!(
            on.remote_fill_frac < off.remote_fill_frac,
            "migration must cut the remote-fill share: {} vs {}",
            on.remote_fill_frac,
            off.remote_fill_frac
        );
    }

    #[test]
    fn render_has_one_row_per_point() {
        let f = ci_fig();
        let t = render(&f);
        assert_eq!(t.rows.len(), f.points.len());
        assert_eq!(f.points.len(), 2 * node_sweep(Scale::Ci).len());
        let md = t.to_markdown();
        assert!(md.contains("remote fill %") && md.contains("hop p99 ns"));
        // the unkilled sweep has no failover table
        assert!(render_failover(&f).is_none());
    }

    /// A killed sweep point auto-extends its arrivals past the kill
    /// time, reports the failover trajectory, and renders the
    /// dip/recovery table.
    #[test]
    fn killed_sweep_reports_dip_and_recovery() {
        let ol = OpenLoopConfig { ops: ops_for(Scale::Ci), ..Default::default() };
        let ol = OpenLoopConfig { rate_per_s: saturating_rate(&ol), ..ol };
        let kill = KillSpec { node: 1, at: Duration::from_us(30) };
        let base = FabricConfig { ol, kill: Some(kill), ..Default::default() };
        let scenario =
            Scenario::preset("hot-kvs", footprint_for(Scale::Ci), 0.99).expect("hot-kvs preset");
        let f = run_custom(base, &scenario, &[3], &[false]);
        assert_eq!(f.points.len(), 1);
        let p = &f.points[0];
        let s = p.failover.as_ref().expect("kill must fire mid-run");
        assert_eq!(s.node, 1);
        assert!((s.killed_us - 30.0).abs() < 1e-6, "killed at the scripted time");
        let detect = s.detect_us.expect("survivors must declare the death");
        assert!(detect > 0.0 && detect <= 40.0, "watchdog bounds detection: {detect}");
        assert!(s.rehomed_lines > 0, "the dead node homed ~a third of the lines");
        // lossless accounting: every op not abandoned with the dead node completed
        let target = ops_covering_kill(ops_for(Scale::Ci), ol.rate_per_s, 3, kill.at);
        assert!(target > ops_for(Scale::Ci), "arrivals must extend past the kill");
        assert_eq!(p.completed + s.abandoned_ops, target);
        let t = render_failover(&f).expect("killed sweep renders the failover table");
        assert_eq!(t.rows.len(), 1);
        assert!(t.to_markdown().contains("dip depth %"));
    }
}
