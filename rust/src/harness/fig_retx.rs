//! Retransmission-discipline ablation: go-back-N vs selective repeat vs
//! selective repeat + adaptive RTO, at matched offered load over the
//! same fault streams (`eci bench retx`).
//!
//! PR 4's goodput figure showed the stack degrading gracefully under
//! loss; this figure asks *how much of the remaining bandwidth the
//! recovery discipline itself burns*. The headline metric is **replay
//! bytes per delivered byte** ([`crate::transport::RelStats::replay_overhead`]):
//! go-back-N re-sends the whole VC tail behind every hole, so its
//! overhead amplifies with BER exactly where the goodput figure gets
//! interesting; selective repeat pays one frame per hole. The sweep
//! reports, per discipline × slice count × BER: delivered goodput,
//! p50/p99 latency, replay overhead, retransmission/timeout counts, and
//! the effective RTO (fixed, or the adaptive estimate in force at the
//! end of the run) — every row self-describing.
//!
//! Shape criteria, asserted at CI scale below and gated in CI via
//! `eci bench retx --ber 1e-3 --seed 7`:
//!
//! * at BER 1e-3 on 4 slices, selective repeat replays **strictly fewer
//!   bytes** than go-back-N at equal-or-better delivered goodput;
//! * the adaptive RTO never fires a timeout on a clean link (pinned
//!   separately in `rust/tests/rel_faults.rs`).

use crate::transport::rel::{RelMode, RelStats};
use crate::workload::openloop::{self, OpenLoopConfig};
use crate::workload::scenario::Scenario;

use super::common::{fmt_rate, ResultTable, Scale};
use super::fig_goodput::{default_rate, FaultKnobs};

/// One retransmission discipline under test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetxVariant {
    pub mode: RelMode,
    pub adaptive_rto: bool,
}

impl RetxVariant {
    pub fn label(&self) -> String {
        super::fig_goodput::rel_label(self.mode, self.adaptive_rto)
    }
}

/// The ablation's fixed variant grid: the PR 4 baseline, the
/// selective-repeat discipline alone, and selective repeat with the
/// RTT-adaptive timer.
pub const VARIANTS: [RetxVariant; 3] = [
    RetxVariant { mode: RelMode::GoBackN, adaptive_rto: false },
    RetxVariant { mode: RelMode::SelectiveRepeat, adaptive_rto: false },
    RetxVariant { mode: RelMode::SelectiveRepeat, adaptive_rto: true },
];

/// Bit-error rates swept by default (high enough that the replay
/// disciplines actually separate).
pub const BER_SWEEP: [f64; 3] = [1e-5, 1e-4, 1e-3];

/// Slice counts swept by default (the acceptance point is 4 slices).
pub const SLICE_SWEEP: [usize; 1] = [4];

/// Arrivals per sweep point at each scale.
pub fn ops_for(scale: Scale) -> u64 {
    match scale {
        Scale::Ci => 1_200,
        Scale::Default => 8_000,
        Scale::Paper => 40_000,
    }
}

/// One sweep point: one discipline at one (slices, BER) cell.
#[derive(Clone, Debug)]
pub struct RetxPoint {
    pub variant: RetxVariant,
    pub slices: usize,
    pub ber: f64,
    pub offered_per_s: f64,
    /// Completed operations per second.
    pub delivered_per_s: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Replay bytes per delivered byte — the figure's headline metric.
    pub replay_overhead: f64,
    /// Absolute replay bytes (both directions).
    pub retransmitted_bytes: u64,
    pub retransmitted: u64,
    pub timeouts: u64,
    pub frame_goodput: f64,
    /// The retransmit timeout in force at the end of the run, ns.
    pub rto_ns: u64,
}

pub struct FigRetx {
    pub scenario: String,
    pub seed: u64,
    pub points: Vec<RetxPoint>,
}

impl FigRetx {
    /// The point for a (variant, slices, ber) cell, if swept.
    pub fn point(&self, variant: RetxVariant, slices: usize, ber: f64) -> Option<&RetxPoint> {
        self.points
            .iter()
            .find(|p| p.variant == variant && p.slices == slices && p.ber == ber)
    }
}

/// Run one discipline at one sweep cell (always through the rel layer).
pub fn run_point(
    cfg: OpenLoopConfig,
    scenario: &Scenario,
    variant: RetxVariant,
    slices: usize,
    ber: f64,
    knobs: FaultKnobs,
    rate: f64,
) -> RetxPoint {
    let knobs = FaultKnobs { mode: variant.mode, adaptive_rto: variant.adaptive_rto, ..knobs };
    let mut cfg = OpenLoopConfig { rate_per_s: rate, seed: knobs.seed, ..cfg };
    cfg.machine.rel = Some(knobs.rel_config(ber));
    let r = openloop::run(cfg, scenario, slices);
    let retx_bytes = r.counters.get("rel_retransmitted_bytes");
    // rebuild the byte counters into a stats snapshot so the overhead
    // ratio has exactly one definition ([`RelStats::replay_overhead`])
    let bytes = RelStats {
        retransmitted_bytes: retx_bytes,
        accepted_bytes: r.counters.get("rel_accepted_bytes"),
        ..Default::default()
    };
    RetxPoint {
        variant,
        slices,
        ber,
        offered_per_s: r.offered_per_s,
        delivered_per_s: r.delivered_per_s,
        p50_ns: r.p50_ns(),
        p99_ns: r.p99_ns(),
        replay_overhead: bytes.replay_overhead(),
        retransmitted_bytes: retx_bytes,
        retransmitted: r.counters.get("rel_retransmitted"),
        timeouts: r.counters.get("rel_timeouts"),
        frame_goodput: r.frame_goodput,
        rto_ns: r.counters.get("rel_rto_ns"),
    }
}

/// Full figure: every discipline over `slices` × `bers` at one offered
/// rate — the `eci bench retx` surface. All three variants see the same
/// traffic and fault seeds, so the comparison isolates the discipline.
pub fn run_custom_with(
    cfg: OpenLoopConfig,
    scenario: &Scenario,
    slices: &[usize],
    bers: &[f64],
    knobs: FaultKnobs,
    rate: f64,
) -> FigRetx {
    let mut points = Vec::new();
    for &variant in &VARIANTS {
        for &n in slices {
            for &ber in bers {
                points.push(run_point(cfg, scenario, variant, n, ber, knobs, rate));
            }
        }
    }
    FigRetx { scenario: scenario.name.clone(), seed: knobs.seed, points }
}

/// The default figure: streaming `scan` traffic, 4 slices, the default
/// BER grid.
pub fn run(scale: Scale) -> FigRetx {
    let cfg = OpenLoopConfig { ops: ops_for(scale), ..Default::default() };
    let scenario = Scenario::preset("scan", super::fig_loadcurve::footprint_for(scale), 0.99)
        .expect("scan preset");
    let rate = default_rate(cfg.machine.home_proc);
    run_custom_with(cfg, &scenario, &SLICE_SWEEP, &BER_SWEEP, FaultKnobs::default(), rate)
}

pub fn render(f: &FigRetx) -> ResultTable {
    let mut t = ResultTable::new(
        &format!(
            "Replay bandwidth vs retransmission discipline, scenario `{}` (seed {:#x})",
            f.scenario, f.seed
        ),
        &[
            "rel",
            "slices",
            "ber",
            "goodput/s",
            "p50 ns",
            "p99 ns",
            "replay B/B",
            "retx bytes",
            "retx",
            "timeouts",
            "rto ns",
        ],
    );
    for p in &f.points {
        t.row(vec![
            p.variant.label(),
            p.slices.to_string(),
            format!("{:.0e}", p.ber),
            fmt_rate(p.delivered_per_s),
            format!("{:.0}", p.p50_ns),
            format!("{:.0}", p.p99_ns),
            format!("{:.4}", p.replay_overhead),
            p.retransmitted_bytes.to_string(),
            p.retransmitted.to_string(),
            p.timeouts.to_string(),
            p.rto_ns.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Acceptance (CI scale): at BER 1e-3 on 4 slices, selective repeat
    /// replays strictly fewer bytes than go-back-N at equal-or-better
    /// delivered goodput, and the adaptive-RTO variant stays in the
    /// same envelope while reporting a measured (sub-fixed) timeout.
    #[test]
    fn sr_replays_fewer_bytes_than_gbn_at_equal_or_better_goodput() {
        let cfg = OpenLoopConfig { ops: ops_for(Scale::Ci), ..Default::default() };
        let scenario = Scenario::preset("scan", 1 << 12, 0.99).unwrap();
        let rate = default_rate(cfg.machine.home_proc);
        let f = run_custom_with(cfg, &scenario, &[4], &[1e-3], FaultKnobs::default(), rate);
        assert_eq!(f.points.len(), 3);
        let gbn = f.point(VARIANTS[0], 4, 1e-3).unwrap();
        let sr = f.point(VARIANTS[1], 4, 1e-3).unwrap();
        let sr_arto = f.point(VARIANTS[2], 4, 1e-3).unwrap();
        // both disciplines actually exercised replay
        assert!(gbn.retransmitted > 0 && sr.retransmitted > 0);
        // the headline: strictly fewer replay bytes ...
        assert!(
            sr.retransmitted_bytes < gbn.retransmitted_bytes,
            "selective repeat must replay strictly fewer bytes: sr {} vs gbn {}",
            sr.retransmitted_bytes,
            gbn.retransmitted_bytes
        );
        assert!(sr.replay_overhead < gbn.replay_overhead);
        // ... at equal-or-better goodput
        assert!(
            sr.delivered_per_s >= gbn.delivered_per_s,
            "selective repeat must not cost goodput: sr {} vs gbn {}",
            sr.delivered_per_s,
            gbn.delivered_per_s
        );
        // the adaptive timer keeps the replay win and reports a
        // measured RTO inside the floor/ceiling clamps
        assert!(sr_arto.retransmitted_bytes < gbn.retransmitted_bytes);
        assert!(sr_arto.delivered_per_s >= gbn.delivered_per_s);
        assert!(
            (1_000..=32_000).contains(&sr_arto.rto_ns),
            "adaptive rto {} ns escaped the clamps",
            sr_arto.rto_ns
        );
        assert_eq!(sr.rto_ns, 2_000, "fixed-timer rows report the configured RTO");
    }

    #[test]
    fn render_has_one_row_per_point_and_is_self_describing() {
        let cfg = OpenLoopConfig { ops: 300, ..Default::default() };
        let scenario = Scenario::preset("scan", 1 << 10, 0.99).unwrap();
        let rate = default_rate(cfg.machine.home_proc);
        let f = run_custom_with(cfg, &scenario, &[1], &[1e-4], FaultKnobs::default(), rate);
        assert_eq!(f.points.len(), VARIANTS.len());
        let md = render(&f).to_markdown();
        assert!(md.contains("replay B/B"));
        assert!(md.contains("gbn") && md.contains("sr+adaptive-rto"));
        assert!(md.contains("seed"), "the header must carry the seed");
    }
}
