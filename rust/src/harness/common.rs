//! Harness shared bits: scale control and markdown/CSV emitters.

/// Experiment scale. The paper's table has 5,120,000 rows (655 MB); the
/// default scale divides workload sizes so the full suite runs in
/// minutes. `ECI_SCALE=paper` (or `full`) runs paper-size workloads;
/// `ECI_SCALE=ci` shrinks further for smoke tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Ci,
    Default,
    Paper,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("ECI_SCALE").as_deref() {
            Ok("paper") | Ok("full") => Scale::Paper,
            Ok("ci") => Scale::Ci,
            _ => Scale::Default,
        }
    }
    /// Scale a paper-sized row count.
    pub fn rows(self, paper_rows: u64) -> u64 {
        match self {
            Scale::Paper => paper_rows,
            Scale::Default => paper_rows / 16,
            Scale::Ci => paper_rows / 256,
        }
    }
    /// Thread counts to sweep.
    pub fn threads(self) -> Vec<usize> {
        match self {
            Scale::Ci => vec![1, 4, 16],
            _ => vec![1, 2, 4, 8, 16, 32, 48],
        }
    }
}

/// A result table: header + rows, printable as markdown and CSV.
#[derive(Clone, Debug, Default)]
pub struct ResultTable {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl ResultTable {
    pub fn new(title: &str, header: &[&str]) -> ResultTable {
        ResultTable {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut s = format!("\n### {}\n\n", self.title);
        s.push_str(&format!("| {} |\n", self.header.join(" | ")));
        s.push_str(&format!("|{}\n", "---|".repeat(self.header.len())));
        for r in &self.rows {
            s.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }

    /// Machine-readable form (`eci bench <id> --json`): rows become
    /// objects keyed by the header; numeric-looking cells become
    /// numbers.
    pub fn to_json(&self) -> crate::obs::Json {
        use crate::obs::Json;
        let rows = self
            .rows
            .iter()
            .map(|r| {
                Json::Obj(
                    self.header
                        .iter()
                        .zip(r)
                        .map(|(h, cell)| {
                            let v = match cell.parse::<f64>() {
                                Ok(n) if n.is_finite() => Json::Num(n),
                                _ => Json::s(cell),
                            };
                            (h.clone(), v)
                        })
                        .collect(),
                )
            })
            .collect();
        Json::Obj(vec![
            ("title".to_string(), Json::s(&self.title)),
            ("rows".to_string(), Json::Arr(rows)),
        ])
    }
}

pub fn fmt_rate(v: f64) -> String {
    if v >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.2}K", v / 1e3)
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_formats() {
        let mut t = ResultTable::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
        let j = t.to_json();
        assert_eq!(j.get("title").and_then(|v| v.as_str()), Some("demo"));
        let rows = j.get("rows").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("a").and_then(|v| v.as_f64()), Some(1.0));
    }

    #[test]
    fn scale_rows() {
        assert_eq!(Scale::Paper.rows(5_120_000), 5_120_000);
        assert_eq!(Scale::Default.rows(5_120_000), 320_000);
    }

    #[test]
    fn rates() {
        assert_eq!(fmt_rate(1.5e9), "1.50G");
        assert_eq!(fmt_rate(2.5e6), "2.50M");
        assert_eq!(fmt_rate(3.0e3), "3.00K");
        assert_eq!(fmt_rate(12.0), "12.00");
    }
}
