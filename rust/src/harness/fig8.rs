//! Figure 8: the effect of temporal locality with ECI (paper §5.7).
//!
//! The regex scan's results are delivered into the CPU's L1/L2 by the
//! coherence protocol, invisibly to software; an application that re-uses
//! results (re-reading N-D, N-2D, ... after reading N) gets them from
//! cache instead of paying the FPGA's recompute cost.
//!
//! Shape criteria: throughput grows ~linearly with the reuse factor
//! (window/D) until the re-read set exceeds the cache (L1 series capped
//! by L1 capacity, L2 series by LLC); the L2 miss-rate curve mirrors it;
//! a single core beats the full-machine no-reuse scan at reuse ≈ 8-16.

use crate::agents::dram::MemStore;
use crate::machine::{map, FpgaApp, Machine, MachineConfig, Workload};
use crate::memctl::ComputeRegion;
use crate::proto::messages::{Line, LineAddr, LINE_BYTES};
use crate::sim::time::Duration;

use super::common::{fmt_rate, ResultTable, Scale};

/// Per-result recompute cost at the FPGA (regex over a 62-char field at
/// 300 MHz ≈ 207 ns, plus dispatch).
pub const RECOMPUTE: Duration = Duration(250_000); // 250 ns

#[derive(Clone, Debug)]
pub struct Fig8Point {
    /// Reuse stride D as a fraction of the cache (window/D = reuse factor).
    pub d_fraction: f64,
    pub cache: &'static str,
    pub reads_per_s: f64,
    pub l2_miss_rate: f64,
    pub reuse_factor: f64,
}

pub fn run_point(results: u64, window_lines: u64, stride: u64, cache: &'static str) -> Fig8Point {
    let cfg = MachineConfig::enzian_eci();
    let fpga_mem = MemStore::new(map::TABLE_BASE, 1 << 20);
    let cpu_mem = MemStore::new(LineAddr(0), 1 << 20);
    // result lines: distinctive content per slot
    let lines: Vec<Box<Line>> = (0..4096u64)
        .map(|i| {
            let mut l = [0u8; LINE_BYTES];
            l[0..8].copy_from_slice(&i.to_le_bytes());
            Box::new(l)
        })
        .collect();
    let region = ComputeRegion::new(4, RECOMPUTE);
    let mut m = Machine::new(cfg, FpgaApp::Result { region, lines }, fpga_mem, cpu_mem);
    m.set_workload(
        Workload::ReuseScan { results, stride, window: window_lines, think: Duration::from_ns(3) },
        1,
    );
    let r = m.run();
    Fig8Point {
        d_fraction: stride as f64 / window_lines as f64,
        cache,
        reads_per_s: r.results_per_s(),
        l2_miss_rate: r.llc_miss_rate(),
        reuse_factor: if stride == 0 { 1.0 } else { (window_lines / stride) as f64 },
    }
}

pub struct Fig8 {
    pub points: Vec<Fig8Point>,
    /// Baseline: no reuse (pure scan), one thread.
    pub baseline_reads_per_s: f64,
}

pub fn run(scale: Scale) -> Fig8 {
    let cfg = MachineConfig::enzian_eci();
    let results = match scale {
        Scale::Ci => 20_000,
        Scale::Default => 60_000,
        Scale::Paper => 400_000,
    };
    // Reuse window = half the cache capacity: the re-read set plus the
    // streaming leading edge must fit without LRU thrash (a window equal
    // to capacity degenerates to cyclic-LRU 0% hits).
    let l1_lines = (cfg.cpu.l1_bytes / LINE_BYTES) as u64 / 2; // 128
    let l2_lines = (cfg.cpu.llc_bytes / LINE_BYTES) as u64 / 8; // 16384
    let mut points = Vec::new();
    // D swept as a fraction of the window: 1/64 .. 1/2 (reuse 64x .. 2x)
    for &frac in &[64u64, 32, 16, 8, 4, 2] {
        points.push(run_point(results, l1_lines, (l1_lines / frac).max(1), "L1"));
    }
    for &frac in &[64u64, 32, 16, 8, 4, 2] {
        points.push(run_point(results, l2_lines, (l2_lines / frac).max(1), "L2"));
    }
    let base = run_point(results, l1_lines, 0, "none");
    Fig8 { points, baseline_reads_per_s: base.reads_per_s }
}

pub fn render(f: &Fig8) -> ResultTable {
    let mut t = ResultTable::new(
        "Figure 8: effect of temporal locality (1 thread, recompute-on-miss)",
        &["cache", "D (frac of window)", "reuse", "reads/s", "L2 miss rate", "vs no-reuse"],
    );
    for p in &f.points {
        t.row(vec![
            p.cache.into(),
            format!("{:.3}", p.d_fraction),
            format!("{:.0}x", p.reuse_factor),
            fmt_rate(p.reads_per_s),
            format!("{:.3}", p.l2_miss_rate),
            format!("{:.1}x", p.reads_per_s / f.baseline_reads_per_s),
        ]);
    }
    t
}
