//! Directory-throughput scaling: sustained coherence operations/sec and
//! tail latency of the sharded directory controller ([`crate::dcs`])
//! under a closed-loop mixed workload, swept over slice counts.
//!
//! This is the reproduction's companion to the paper's even/odd VC-pair
//! observation (§4.2): address-interleaved directory slices are what let
//! coherence throughput scale with parallel protocol engines. Shape
//! criterion: sustained ops/s is monotonically non-decreasing in the
//! slice count, roughly doubling while the slice pipeline is the
//! bottleneck and flattening once the offered load (clients / round-trip)
//! or the DRAM/KVS backends bind.
//!
//! The sweep can additionally carry *cached* configurations
//! ([`DcsConfig::cached`] / `eci bench dcs --cached-slices`): the
//! symmetric sliced directory, where each slice fronts a partition of
//! the home-cache budget and repeat reads skip the backing-store round
//! trip. On the hot-kvs-shaped closed loop ([`hot_kvs_cfg`],
//! Zipf-skewed, read-mostly) the cached configuration beats cache-less
//! slices at equal slice count — pinned by a test below.

use crate::dcs::loadgen::{self, LoadGenConfig, MixConfig};
use crate::dcs::DcsConfig;

use super::common::{fmt_rate, ResultTable, Scale};

/// Slice counts swept by default.
pub const SLICE_SWEEP: [usize; 4] = [1, 2, 4, 8];

#[derive(Clone, Debug)]
pub struct ThroughputPoint {
    pub slices: usize,
    /// Slice-local home caches present?
    pub cached: bool,
    pub ops_per_s: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub p999_ns: f64,
    /// Mean slice-pipeline occupancy (0..1).
    pub occupancy: f64,
    pub per_slice_served: Vec<u64>,
    /// Reads served from the slice-local home caches.
    pub home_hits: u64,
}

pub struct FigThroughput {
    pub cfg: LoadGenConfig,
    pub points: Vec<ThroughputPoint>,
}

/// Total operations per run at each scale (shared with the CLI defaults
/// so `eci bench dcs` and the bench sweep drive the same workload).
pub fn ops_for(scale: Scale) -> u64 {
    match scale {
        Scale::Ci => 4_000,
        Scale::Default => 20_000,
        Scale::Paper => 100_000,
    }
}

/// One sweep point against an explicit dcs shape (slice count, home
/// cache, ingress batch).
pub fn run_point_dcs(cfg: LoadGenConfig, dcs: DcsConfig) -> ThroughputPoint {
    let slices = dcs.slices;
    let cached = dcs.home_cached();
    let r = loadgen::run(cfg, dcs);
    let occupancy = if r.per_slice_occupancy.is_empty() {
        0.0
    } else {
        r.per_slice_occupancy.iter().sum::<f64>() / r.per_slice_occupancy.len() as f64
    };
    ThroughputPoint {
        slices,
        cached,
        ops_per_s: r.ops_per_s,
        p50_ns: r.p50_ns(),
        p99_ns: r.p99_ns(),
        p999_ns: r.p999_ns(),
        occupancy,
        home_hits: r.counters.get("home_cache_hit"),
        per_slice_served: r.per_slice_served,
    }
}

/// One sweep point: the configured workload against `slices` cache-less
/// slices, using [`DcsConfig::new`]'s slice-pipeline calibration (~12
/// fabric cycles at 300 MHz, the Enzian `home_proc`).
pub fn run_point(cfg: LoadGenConfig, slices: usize) -> ThroughputPoint {
    run_point_dcs(cfg, DcsConfig::new(slices))
}

/// Sweep the given slice counts with one workload configuration.
pub fn run_with(cfg: LoadGenConfig, slices: &[usize]) -> FigThroughput {
    run_with_variants(cfg, slices, &[], 1)
}

/// Full sweep: cache-less points for `slices`, cached points
/// ([`DcsConfig::cached`]) for `cached_slices`, all with ingress batch
/// size `batch` — the `eci bench dcs --slices/--cached-slices/--batch`
/// surface.
pub fn run_with_variants(
    cfg: LoadGenConfig,
    slices: &[usize],
    cached_slices: &[usize],
    batch: usize,
) -> FigThroughput {
    let mut points: Vec<ThroughputPoint> = slices
        .iter()
        .map(|&n| run_point_dcs(cfg, DcsConfig::new(n).with_batch(batch)))
        .collect();
    points.extend(
        cached_slices
            .iter()
            .map(|&n| run_point_dcs(cfg, DcsConfig::cached(n).with_batch(batch))),
    );
    FigThroughput { cfg, points }
}

/// The hot-kvs-shaped closed-loop workload: Zipf(0.99) popularity,
/// read-mostly with short chases, few enough clients to stay
/// latency-bound — the operating point where slice-local home caching
/// shows up in sustained throughput.
pub fn hot_kvs_cfg(scale: Scale) -> LoadGenConfig {
    LoadGenConfig {
        ops: ops_for(scale),
        clients: 8,
        region_lines: 1 << 13,
        theta: 0.99,
        mix: MixConfig { reads: 70, writes: 10, chases: 20, chase_hops: 2 },
        ..Default::default()
    }
}

/// Cached-vs-plain comparison on the hot-kvs workload: one cache-less
/// and one cached point per slice count.
pub fn run_cached_comparison(scale: Scale, slices: &[usize], batch: usize) -> FigThroughput {
    let cfg = hot_kvs_cfg(scale);
    run_with_variants(cfg, slices, slices, batch)
}

/// The default figure: mixed read/write/pointer-chase workload from 32
/// closed-loop clients, slice counts 1/2/4/8.
pub fn run(scale: Scale) -> FigThroughput {
    let cfg =
        LoadGenConfig { ops: ops_for(scale), mix: MixConfig::default(), ..Default::default() };
    run_with(cfg, &SLICE_SWEEP)
}

pub fn render(f: &FigThroughput) -> ResultTable {
    let mix = f.cfg.mix;
    let mut t = ResultTable::new(
        &format!(
            "Directory throughput vs slice count ({} clients, mix r:w:c = {}:{}:{}, {} hops{})",
            f.cfg.clients,
            mix.reads,
            mix.writes,
            mix.chases,
            mix.chase_hops,
            if f.cfg.theta > 0.0 { format!(", Zipf {}", f.cfg.theta) } else { String::new() },
        ),
        &["slices", "config", "ops/s", "p50 ns", "p99 ns", "p999 ns", "occupancy", "home hits", "per-slice served"],
    );
    for p in &f.points {
        t.row(vec![
            p.slices.to_string(),
            if p.cached { "cached".into() } else { "plain".into() },
            fmt_rate(p.ops_per_s),
            format!("{:.0}", p.p50_ns),
            format!("{:.0}", p.p99_ns),
            format!("{:.0}", p.p999_ns),
            format!("{:.2}", p.occupancy),
            p.home_hits.to_string(),
            format!("{:?}", p.per_slice_served),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance shape: sustained ops/s must be monotonically
    /// non-decreasing from 1 to 4 slices under the mixed workload.
    #[test]
    fn throughput_monotone_in_slice_count() {
        let f = run(Scale::Ci);
        assert_eq!(f.points.len(), SLICE_SWEEP.len());
        for w in f.points.windows(2).take(2) {
            assert!(
                w[1].ops_per_s >= w[0].ops_per_s,
                "{} slices {} ops/s < {} slices {} ops/s",
                w[1].slices,
                w[1].ops_per_s,
                w[0].slices,
                w[0].ops_per_s
            );
        }
        // and sharding must actually help while the pipeline binds
        let p1 = &f.points[0];
        let p4 = &f.points[2];
        assert!(
            p4.ops_per_s > p1.ops_per_s * 1.3,
            "4 slices {} vs 1 slice {}",
            p4.ops_per_s,
            p1.ops_per_s
        );
        // the monolith must actually be the bottleneck for this to be a
        // scaling experiment at all
        assert!(p1.occupancy > 0.5, "1-slice occupancy {}", p1.occupancy);
    }

    /// The tentpole acceptance shape: on the hot-kvs workload, the
    /// cached sliced configuration must beat cache-less slices at equal
    /// slice count.
    #[test]
    fn cached_slices_beat_plain_on_hot_kvs() {
        let f = run_cached_comparison(Scale::Ci, &[4], 1);
        assert_eq!(f.points.len(), 2);
        let plain = f.points.iter().find(|p| !p.cached).unwrap();
        let cached = f.points.iter().find(|p| p.cached).unwrap();
        assert_eq!(plain.slices, cached.slices);
        assert_eq!(plain.home_hits, 0);
        assert!(cached.home_hits > 0, "hot reads must hit the home cache");
        assert!(
            cached.ops_per_s > plain.ops_per_s,
            "cached {} ops/s must beat plain {} ops/s at {} slices",
            cached.ops_per_s,
            plain.ops_per_s,
            plain.slices
        );
    }

    #[test]
    fn render_has_one_row_per_point() {
        let cfg = LoadGenConfig { ops: 500, clients: 4, ..Default::default() };
        let f = run_with_variants(cfg, &[1, 2], &[2], 2);
        let t = render(&f);
        assert_eq!(t.rows.len(), 3);
        let md = t.to_markdown();
        assert!(md.contains("slices"));
        assert!(md.contains("cached"));
        assert!(md.contains("plain"));
    }
}
