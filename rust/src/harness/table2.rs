//! Table 2: ECI hardware resource consumption on the VU9P, plus the
//! subsetting ablation the resource model enables.

use crate::proto::subset::Subset;
use crate::resource::{eci_stack, percentages, totals, StackConfig};

use super::common::ResultTable;

pub fn render() -> Vec<ResultTable> {
    let mut out = Vec::new();

    // the paper's table
    let comps = eci_stack(StackConfig::reference());
    let t = totals(&comps);
    let (pl, pr, pb) = percentages(&t);
    let mut t2 = ResultTable::new(
        "Table 2: ECI resource consumption on a Xilinx VU9P (paper: 46186 / 32777 / 112.5 = 3.91% / 1.39% / 5.23%)",
        &["", "LUTs", "REGs", "BRAM(36Kb)"],
    );
    t2.row(vec![
        "ECI per link".into(),
        t.luts.to_string(),
        t.regs.to_string(),
        format!("{:.1}", t.bram36),
    ]);
    t2.row(vec![
        "Percentage".into(),
        format!("{pl:.2}%"),
        format!("{pr:.2}%"),
        format!("{pb:.2}%"),
    ]);
    out.push(t2);

    // per-component breakdown (our accounting)
    let mut bd = ResultTable::new(
        "Table 2 (breakdown): per-component estimates",
        &["component", "LUTs", "REGs", "BRAM(36Kb)"],
    );
    for c in &comps {
        bd.row(vec![
            c.name.clone(),
            c.luts.to_string(),
            c.regs.to_string(),
            format!("{:.1}", c.bram36),
        ]);
    }
    out.push(bd);

    // subsetting ablation (the §3.4 space argument, quantified)
    let mut ab = ResultTable::new(
        "Table 2 (ablation): protocol subsetting vs. area",
        &["subset", "home states", "LUTs", "REGs", "BRAM(36Kb)"],
    );
    for s in [
        Subset::full_symmetric(),
        Subset::asymmetric_accelerator(),
        Subset::cpu_initiator_readonly(),
        Subset::stateless_readonly(),
    ] {
        let t = totals(&eci_stack(StackConfig::for_subset(&s)));
        ab.row(vec![
            s.name.into(),
            s.home_state_count().to_string(),
            t.luts.to_string(),
            t.regs.to_string(),
            format!("{:.1}", t.bram36),
        ]);
    }
    out.push(ab);
    out
}
