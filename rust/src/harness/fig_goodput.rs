//! Goodput and tail latency vs bit-error rate, per slice count — the
//! reliability subsystem's headline figure (`eci bench faults`).
//!
//! A fixed, comfortably sub-knee offered rate is swept over a grid of
//! bit-error rates (optionally with whole-frame drops, reordering, and
//! burst errors) on the lossy-link stack ([`crate::transport::rel`]):
//! per-VC go-back-N replay beneath the sliced directory. Two shape
//! criteria, both asserted at CI scale below:
//!
//! * **graceful degradation** — delivered goodput sinks *smoothly* as
//!   replays burn link bandwidth, still clearing a healthy fraction of
//!   the clean-link rate at BER 1e-3 on 4 slices (no collapse), while
//!   p99 latency climbs — loss is a tail event first;
//! * **loss transparency** — the settled end state (per-line directory
//!   states + backing-store bytes) is bit-identical with faults on vs
//!   off: loss changes timing, never semantics.

use crate::sim::time::Duration;
use crate::transport::rel::{FaultConfig, FaultSpec, RelConfig, RelMode};
use crate::workload::openloop::{self, OpenLoopConfig};
use crate::workload::scenario::Scenario;

use super::common::{fmt_rate, ResultTable, Scale};
use super::fig_loadcurve::base_rate;

/// Bit-error rates swept by default (0 = the clean baseline, through
/// the rel layer so the comparison is apples to apples).
pub const BER_SWEEP: [f64; 5] = [0.0, 1e-6, 1e-5, 1e-4, 1e-3];

/// Slice counts swept by default (the acceptance point is 4 slices).
pub const SLICE_SWEEP: [usize; 2] = [1, 4];

/// Arrivals per sweep point at each scale.
pub fn ops_for(scale: Scale) -> u64 {
    match scale {
        Scale::Ci => 1_200,
        Scale::Default => 8_000,
        Scale::Paper => 40_000,
    }
}

/// The fixed offered rate of the sweep: ~1/4 of the one-slice streaming
/// capacity, so every configuration is sub-knee on a clean link and any
/// degradation is attributable to the injected faults.
pub fn default_rate(slice_proc: Duration) -> f64 {
    0.25 * base_rate(slice_proc)
}

/// Non-BER fault knobs shared by every point of a sweep.
#[derive(Clone, Copy, Debug)]
pub struct FaultKnobs {
    /// Per-frame whole-loss probability.
    pub drop: f64,
    /// Per-frame reorder (late-delivery) probability.
    pub reorder: f64,
    /// Mean error-burst length in frames (1 = independent errors).
    pub burst_len: f64,
    /// Injector seed (`--seed`; also reseeds the traffic draws).
    pub seed: u64,
    /// Retransmission discipline (`--mode gbn|sr`).
    pub mode: RelMode,
    /// RTT-adaptive retransmit timeout (`--adaptive-rto`).
    pub adaptive_rto: bool,
}

impl Default for FaultKnobs {
    fn default() -> FaultKnobs {
        FaultKnobs {
            drop: 0.0,
            reorder: 0.0,
            burst_len: 1.0,
            seed: OpenLoopConfig::default().seed,
            mode: RelMode::GoBackN,
            adaptive_rto: false,
        }
    }
}

/// The one canonical spelling of a retransmission-discipline label
/// (`gbn`, `sr`, `sr+adaptive-rto`) — figure headers and rows must
/// agree on it, so both the faults and retx figures format through
/// here.
pub fn rel_label(mode: RelMode, adaptive_rto: bool) -> String {
    format!("{}{}", mode.name(), if adaptive_rto { "+adaptive-rto" } else { "" })
}

impl FaultKnobs {
    /// The rel-layer configuration of one sweep point.
    pub fn rel_config(&self, ber: f64) -> RelConfig {
        let spec = FaultSpec { ber, drop: self.drop, reorder: self.reorder, burst_len: self.burst_len };
        RelConfig::new(FaultConfig::new(spec, self.seed))
            .with_mode(self.mode)
            .with_adaptive_rto(self.adaptive_rto)
    }

    /// Human-readable description of the retransmission discipline
    /// (figure headers: a run must be self-describing).
    pub fn rel_label(&self) -> String {
        rel_label(self.mode, self.adaptive_rto)
    }
}

/// One sweep point.
#[derive(Clone, Debug)]
pub struct GoodputPoint {
    pub slices: usize,
    /// Slice-local home caches present?
    pub home_cached: bool,
    pub ber: f64,
    pub offered_per_s: f64,
    /// Completed operations per second — the figure's goodput.
    pub delivered_per_s: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    /// Fraction of transmitted link frames that were useful.
    pub frame_goodput: f64,
    pub retransmitted: u64,
    pub timeouts: u64,
    /// High-water mark of the replay-buffer occupancy (frames).
    pub peak_replay: u64,
    /// The retransmit timeout in force at the end of the run, ns (the
    /// fixed value, or the clamped adaptive estimate).
    pub rto_ns: u64,
}

pub struct FigGoodput {
    pub scenario: String,
    /// Retransmission-discipline label (`gbn`, `sr`, `sr+adaptive-rto`)
    /// — the figure header must make a run self-describing.
    pub rel: String,
    /// The seed the whole run derives from (traffic + fault streams).
    pub seed: u64,
    pub points: Vec<GoodputPoint>,
}

/// One sweep point: `scenario` at `rate` against `slices` slices with
/// the given BER + knobs (always through the rel layer, clean or not).
pub fn run_point(
    cfg: OpenLoopConfig,
    scenario: &Scenario,
    slices: usize,
    ber: f64,
    knobs: FaultKnobs,
    rate: f64,
) -> GoodputPoint {
    let mut cfg = OpenLoopConfig { rate_per_s: rate, seed: knobs.seed, ..cfg };
    cfg.machine.rel = Some(knobs.rel_config(ber));
    let r = openloop::run(cfg, scenario, slices);
    GoodputPoint {
        slices,
        home_cached: cfg.home_cached,
        ber,
        offered_per_s: r.offered_per_s,
        delivered_per_s: r.delivered_per_s,
        p50_ns: r.p50_ns(),
        p99_ns: r.p99_ns(),
        frame_goodput: r.frame_goodput,
        retransmitted: r.counters.get("rel_retransmitted"),
        timeouts: r.counters.get("rel_timeouts"),
        peak_replay: r.counters.get("rel_peak_replay"),
        rto_ns: r.counters.get("rel_rto_ns"),
    }
}

/// Full figure: every slice count (plain, then `cached_slices` with
/// slice-local home caches) over the same BER grid at one offered rate
/// — the `eci bench faults --slices/--cached-slices/--ber` surface.
pub fn run_custom_with(
    cfg: OpenLoopConfig,
    scenario: &Scenario,
    slices: &[usize],
    cached_slices: &[usize],
    bers: &[f64],
    knobs: FaultKnobs,
    rate: f64,
) -> FigGoodput {
    let mut points = Vec::new();
    for &n in slices {
        for &ber in bers {
            points.push(run_point(cfg, scenario, n, ber, knobs, rate));
        }
    }
    let cached_cfg = OpenLoopConfig { home_cached: true, ..cfg };
    for &n in cached_slices {
        for &ber in bers {
            points.push(run_point(cached_cfg, scenario, n, ber, knobs, rate));
        }
    }
    FigGoodput {
        scenario: scenario.name.clone(),
        rel: knobs.rel_label(),
        seed: knobs.seed,
        points,
    }
}

/// The default figure: streaming `scan` traffic (write-free, so the
/// loss-transparency digest is meaningful), slice counts 1/4, the
/// default BER grid.
pub fn run(scale: Scale) -> FigGoodput {
    let cfg = OpenLoopConfig { ops: ops_for(scale), ..Default::default() };
    let scenario = Scenario::preset("scan", super::fig_loadcurve::footprint_for(scale), 0.99)
        .expect("scan preset");
    let rate = default_rate(cfg.machine.home_proc);
    run_custom_with(cfg, &scenario, &SLICE_SWEEP, &[], &BER_SWEEP, FaultKnobs::default(), rate)
}

pub fn render(f: &FigGoodput) -> ResultTable {
    let mut t = ResultTable::new(
        &format!(
            "Goodput vs bit-error rate, scenario `{}` (lossy link, rel mode `{}`, seed {:#x})",
            f.scenario, f.rel, f.seed
        ),
        &[
            "slices",
            "config",
            "ber",
            "offered/s",
            "goodput/s",
            "p50 ns",
            "p99 ns",
            "frame goodput",
            "retx",
            "timeouts",
            "peak replay",
            "rto ns",
        ],
    );
    for p in &f.points {
        t.row(vec![
            p.slices.to_string(),
            if p.home_cached { "cached".into() } else { "plain".into() },
            format!("{:.0e}", p.ber),
            fmt_rate(p.offered_per_s),
            fmt_rate(p.delivered_per_s),
            format!("{:.0}", p.p50_ns),
            format!("{:.0}", p.p99_ns),
            format!("{:.3}", p.frame_goodput),
            p.retransmitted.to_string(),
            p.timeouts.to_string(),
            p.peak_replay.to_string(),
            p.rto_ns.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Acceptance: goodput degrades gracefully (not a collapse) up to
    /// BER 1e-3 at 4 slices, and loss is a tail event — p99 climbs
    /// while the link stays functional (CI scale).
    #[test]
    fn goodput_degrades_gracefully_to_ber_1e3_at_4_slices() {
        let cfg = OpenLoopConfig { ops: ops_for(Scale::Ci), ..Default::default() };
        let scenario = Scenario::preset("scan", 1 << 12, 0.99).unwrap();
        let rate = default_rate(cfg.machine.home_proc);
        let f = run_custom_with(
            cfg,
            &scenario,
            &[4],
            &[],
            &[0.0, 1e-4, 1e-3],
            FaultKnobs::default(),
            rate,
        );
        assert_eq!(f.points.len(), 3);
        let clean = &f.points[0];
        let mid = &f.points[1];
        let worst = &f.points[2];
        assert!(clean.frame_goodput > 0.999, "clean link must waste nothing");
        assert_eq!(clean.retransmitted, 0);
        // every point completes its offered work (delivered > 0) and the
        // lossy points actually exercised replay
        assert!(worst.retransmitted > mid.retransmitted);
        assert!(mid.retransmitted > 0);
        // frame goodput sinks monotonically with BER
        assert!(mid.frame_goodput < clean.frame_goodput);
        assert!(worst.frame_goodput < mid.frame_goodput);
        // graceful: at BER 1e-3 the stack still clears >= 25% of the
        // clean goodput (collapse would be orders of magnitude)
        assert!(
            worst.delivered_per_s >= 0.25 * clean.delivered_per_s,
            "goodput collapsed: {} vs clean {}",
            worst.delivered_per_s,
            clean.delivered_per_s
        );
        // and loss shows up in the tail first
        assert!(
            worst.p99_ns > clean.p99_ns,
            "replays must cost tail latency: {} vs {}",
            worst.p99_ns,
            clean.p99_ns
        );
        assert!(worst.peak_replay > 0);
    }

    /// Acceptance: loss changes timing, never semantics — the settled
    /// end state (per-line directory states + backing-store bytes) is
    /// bit-identical with fault injection on vs off, and vs the plain
    /// (rel-less) stack. Scan is write-free, so the digest is exact.
    #[test]
    fn loss_is_transparent_to_the_settled_end_state() {
        let scenario = Scenario::preset("scan", 1 << 10, 0.99).unwrap();
        let run_with = |rel: Option<RelConfig>| {
            let mut cfg = OpenLoopConfig { rate_per_s: 2e6, ops: 600, ..Default::default() };
            cfg.machine.rel = rel;
            openloop::OpenLoop::new(cfg, &scenario, 2).run_settled()
        };
        let knobs = FaultKnobs { drop: 0.02, reorder: 0.02, ..FaultKnobs::default() };
        let (r_plain, d_plain) = run_with(None);
        let (r_clean, d_clean) = run_with(Some(knobs.rel_config(0.0)));
        let (r_lossy, d_lossy) = run_with(Some(knobs.rel_config(1e-3)));
        assert_eq!(r_plain.completed, 600);
        assert_eq!(r_clean.completed, 600);
        assert_eq!(r_lossy.completed, 600);
        assert!(r_lossy.counters.get("rel_retransmitted") > 0, "faults must have fired");
        assert_eq!(d_clean, d_plain, "the clean rel layer must be invisible");
        assert_eq!(d_lossy, d_plain, "loss must be invisible to the end state");
    }

    #[test]
    fn render_has_one_row_per_point() {
        let cfg = OpenLoopConfig { ops: 300, ..Default::default() };
        let scenario = Scenario::preset("scan", 1 << 10, 0.99).unwrap();
        let rate = default_rate(cfg.machine.home_proc);
        let f = run_custom_with(
            cfg,
            &scenario,
            &[1],
            &[1],
            &[0.0, 1e-4],
            FaultKnobs::default(),
            rate,
        );
        assert_eq!(f.points.len(), 4);
        let md = render(&f).to_markdown();
        assert!(md.contains("frame goodput"));
        assert!(md.contains("cached") && md.contains("plain"));
    }
}
