//! Figure 6: pointer-chasing throughput on CPU and FPGA for varying chain
//! lengths (paper §5.5) — the paper's deliberate *negative* result for
//! the FPGA offload.
//!
//! Shape criteria: CPU >= FPGA at every chain length (big caches + faster
//! random-access memory path win); the FPGA's length-1 point shows the
//! interconnect-saturation cap; both decline ~1/chain_len.

use crate::agents::dram::MemStore;
use crate::anyhow;
use crate::machine::{map, FpgaApp, Machine, MachineConfig, Workload};
use crate::memctl::KvsService;
use crate::operators::kvs::{fpga_hash_batch, lookup};
use crate::operators::table::{build_kvs, KvsSpec};
use crate::proto::messages::{LineAddr, LINE_BYTES};
use crate::runtime::Runtime;

use super::common::{fmt_rate, ResultTable, Scale};

pub const PAPER_ENTRIES: u64 = 5_120_000;
pub const FPGA_ENGINES: usize = 32;

#[derive(Clone, Debug)]
pub struct Fig6Point {
    pub chain_len: u64,
    pub keys_per_s: f64,
    pub dram_gbps: f64,
}

/// FPGA path: requests dispatched over ECI to the engine pool.
pub fn run_fpga(
    rt: &mut Runtime,
    entries: u64,
    chain_len: u64,
    threads: usize,
    lookups: u64,
) -> anyhow::Result<Fig6Point> {
    let spec = KvsSpec { entries, chain_len, seed: 11 };
    let store_lines = 2 * entries + 1024;
    let mut store = MemStore::new(map::TABLE_BASE, store_lines as usize * LINE_BYTES);
    let layout = build_kvs(&spec, &mut store);

    // request stream: last key of each chain (forces full-length chases),
    // hashed through the AOT kernel (functional verification of routing)
    let keys: Vec<i32> = (0..lookups)
        .map(|i| layout.tail_keys[(i % layout.n_buckets) as usize])
        .collect();
    let _buckets = fpga_hash_batch(rt, &keys[..keys.len().min(4096)], layout.bucket_mask)?;

    let requests: Vec<(u64, Box<crate::proto::messages::Line>)> = keys
        .iter()
        .map(|&k| {
            let r = lookup(&store, &layout, k);
            assert!(r.found, "tail key must resolve");
            (r.hops, Box::new([0u8; LINE_BYTES])) // value payload content elided
        })
        .collect();

    let cfg = MachineConfig::enzian_eci();
    let cpu_mem = MemStore::new(LineAddr(0), 1 << 20);
    let svc = KvsService::new(FPGA_ENGINES);
    let mut m = Machine::new(cfg, FpgaApp::Kvs { svc, requests }, store, cpu_mem);
    m.set_workload(Workload::KvsRemote { lookups }, threads);
    let r = m.run();
    Ok(Fig6Point {
        chain_len,
        keys_per_s: r.results_per_s(),
        dram_gbps: r.fpga_dram_bytes as f64 / r.sim_time.as_secs() / 1e9,
    })
}

/// CPU baseline: identical lookups against local memory.
pub fn run_cpu(entries: u64, chain_len: u64, threads: usize, lookups: u64) -> Fig6Point {
    let spec = KvsSpec { entries, chain_len, seed: 11 };
    let store_lines = 2 * entries + 1024;
    let mut store = MemStore::new(LineAddr(0), store_lines as usize * LINE_BYTES);
    let layout = build_kvs(&spec, &mut store);

    // per-lookup dependent chains (bucket line + entries), precomputed
    // functionally; the machine walks them through the cache hierarchy
    let mut chains = Vec::with_capacity(layout.n_buckets as usize);
    for b in 0..layout.n_buckets {
        let key = layout.tail_keys[b as usize];
        let mut chain = Vec::with_capacity(chain_len as usize + 1);
        let bline = layout.base.0 + b / 16;
        chain.push(LineAddr(bline));
        let boff = ((b % 16) * 8) as usize;
        let l = store.read_line(LineAddr(bline));
        let mut ptr = u64::from_le_bytes(l[boff..boff + 8].try_into().unwrap());
        while ptr != crate::operators::table::NULL_PTR {
            chain.push(LineAddr(ptr));
            let e = store.read_line(LineAddr(ptr));
            let k = u64::from_le_bytes(e[0..8].try_into().unwrap()) as u32 as i32;
            if k == key {
                break;
            }
            ptr = u64::from_le_bytes(e[120..128].try_into().unwrap());
        }
        chains.push(chain);
    }

    let cfg = MachineConfig::enzian_eci();
    let fpga_mem = MemStore::new(map::TABLE_BASE, 1 << 20);
    let mut m = Machine::memory_node(cfg, fpga_mem, store);
    m.set_workload(Workload::KvsLocal { chains, lookups }, threads);
    let r = m.run();
    Fig6Point {
        chain_len,
        keys_per_s: r.results_per_s(),
        dram_gbps: r.cpu_dram_bytes as f64 / r.sim_time.as_secs() / 1e9,
    }
}

pub struct Fig6 {
    pub fpga: Vec<Fig6Point>,
    pub cpu: Vec<Fig6Point>,
}

pub fn run(rt: &mut Runtime, scale: Scale) -> anyhow::Result<Fig6> {
    let entries = scale.rows(PAPER_ENTRIES).max(16_384);
    let lookups = scale.rows(400_000).max(4_000);
    let threads = match scale {
        Scale::Ci => 8,
        _ => 32,
    };
    let mut fpga = Vec::new();
    let mut cpu = Vec::new();
    for &cl in &[1u64, 2, 4, 8, 16, 32, 64, 128] {
        fpga.push(run_fpga(rt, entries, cl, threads, lookups)?);
        cpu.push(run_cpu(entries, cl, threads, lookups));
    }
    Ok(Fig6 { fpga, cpu })
}

pub fn render(f: &Fig6) -> ResultTable {
    let mut t = ResultTable::new(
        "Figure 6: pointer-chasing throughput vs. chain length (negative result: CPU wins)",
        &["chain len", "FPGA keys/s", "FPGA DRAM GB/s", "CPU keys/s", "CPU DRAM GB/s"],
    );
    for (pf, pc) in f.fpga.iter().zip(&f.cpu) {
        t.row(vec![
            pf.chain_len.to_string(),
            fmt_rate(pf.keys_per_s),
            format!("{:.2}", pf.dram_gbps),
            fmt_rate(pc.keys_per_s),
            format!("{:.2}", pc.dram_gbps),
        ]);
    }
    t
}
