//! Experiment harness: one driver per table/figure of the paper's §5
//! (see DESIGN.md §4 for the experiment index and shape criteria).
//! `cargo bench` wraps these; the `eci bench <id>` CLI subcommand runs
//! them directly.

pub mod common;
pub mod fig5;
pub mod fig_fabric;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig_goodput;
pub mod fig_loadcurve;
pub mod fig_reconfig;
pub mod fig_retx;
pub mod fig_throughput;
pub mod selfperf;
pub mod table2;
pub mod table3;
pub mod waterfall;

pub use common::{fmt_rate, ResultTable, Scale};
