//! Vendored minimal `anyhow` shim (the offline registry has no
//! third-party crates — same policy as [`crate::sim::rng`] and
//! [`crate::ptest`]). Implements the subset this crate uses: a
//! string-backed [`Error`], [`Result`], the [`Context`] extension for
//! `Result`/`Option`, and the [`anyhow!`]/[`bail!`] macros.
//!
//! Like the real crate, [`Error`] deliberately does *not* implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion (powering `?`) coherent.

use std::fmt;

/// A chain of context strings, innermost cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost context string.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `expect`/`unwrap` print Debug: show the full context chain.
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with a defaulted error type, as in the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option` error path.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::anyhow::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow::Error::msg(format!($($arg)*)))
    };
}

// Make `use crate::anyhow::{anyhow, bail}` work: #[macro_export] puts the
// macros at the crate root; re-import them under this module's path.
pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broke at {}", 7);
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke at 7");
    }

    #[test]
    fn context_on_option_and_result() {
        let none: Option<u32> = None;
        let e = none.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");

        let io: std::result::Result<u32, std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = io.with_context(|| format!("reading {}", "x.json")).unwrap_err();
        let s = e.to_string();
        assert!(s.starts_with("reading x.json: "), "{s}");
        assert!(s.contains("gone"), "{s}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u64> {
            Ok(s.parse::<u64>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn anyhow_macro_builds_errors() {
        let e: Error = anyhow!("bad value {}", 3);
        assert_eq!(e.to_string(), "bad value 3");
    }
}
