//! `SystemSpec` — the one typed, validated description of a simulated
//! system.
//!
//! Before this existed, every host composed its shape from loose
//! parts: an [`OpenLoopConfig`] here, a slice count passed alongside
//! it there, a [`FabricConfig`] wrapping both, and per-bench CLI
//! parsers each re-implementing `--slices/--rate/--seed`. The spec
//! centralizes that: one struct owns the full shape (machine wiring,
//! directory slicing, traffic, fabric topology, scripted failures and
//! reconfigurations), validates it as a whole ([`SystemSpec::validate`]
//! walks the reconfig script with shape tracking, so `drain:1` after
//! `reslice:1` is rejected *before* the run), and derives the
//! plane-level configs from it (`From<&SystemSpec>` for
//! [`OpenLoopConfig`], [`DcsConfig`], [`FabricConfig`] — the old
//! structs stay as internal plumbing).
//!
//! The control plane ([`crate::ctrl`]) holds a `SystemSpec` as the
//! canonical "current shape" and mutates *it* on every live
//! transition; hosts re-derive the plane configs from the mutated
//! spec, so there is exactly one place the running shape lives.
//!
//! [`SystemSpec::FIELDS`] is the CLI metadata table: every common
//! flag's spelling, metavar, help line, and apply function in one
//! place, so `eci bench` subcommands parse shared flags identically
//! ([`SystemSpec::apply_flag`]).

use crate::ctrl::{ReconfigEvent, ReconfigKind};
use crate::dcs::DcsConfig;
use crate::fabric::{FabricConfig, KillSpec};
use crate::machine::MachineConfig;
use crate::sim::time::Duration;
use crate::workload::arrival::ArrivalKind;
use crate::workload::openloop::OpenLoopConfig;

/// The full shape of one simulated system. Not `Copy` (it carries the
/// reconfig script), but cheap to clone.
#[derive(Clone, Debug)]
pub struct SystemSpec {
    /// Node wiring: link credits/framing, slice pipeline, control-path
    /// latency, FPGA DRAM, home-cache budget, reliability.
    pub machine: MachineConfig,
    /// Directory slices per node.
    pub slices: usize,
    /// Slices carry partitions of the machine's home-cache budget.
    pub home_cached: bool,
    /// One slice is administratively drained; its range re-homes
    /// across the survivors (normally set mid-run by `drain:`).
    pub dead_slice: Option<usize>,
    /// Offered arrival rate, operations/second (per node).
    pub rate_per_s: f64,
    pub arrivals: ArrivalKind,
    /// Total arrivals to generate (fabric-wide when `nodes > 1`).
    pub ops: u64,
    /// Caching client (loadgen-style shared LLC) instead of the
    /// streaming default.
    pub cached_client: bool,
    /// Client-side processing between dependent chase hops.
    pub hop_think: Duration,
    /// KVS engine-pool size backing chase resolution at the home.
    pub kvs_engines: usize,
    pub seed: u64,
    /// Fabric width (1 = a single two-socket cell).
    pub nodes: u8,
    /// Threshold-based home migration across the fabric.
    pub migrate: bool,
    /// Remote requests from one node before its lines migrate toward
    /// it.
    pub threshold: u32,
    /// Watchdog bound on whole-node failure detection.
    pub detect: Duration,
    /// Scripted whole-node failure.
    pub kill: Option<KillSpec>,
    /// Scripted live reconfigurations (`--reconfig`, repeatable).
    pub reconfig: Vec<ReconfigEvent>,
}

impl Default for SystemSpec {
    fn default() -> SystemSpec {
        let ol = OpenLoopConfig::default();
        SystemSpec {
            machine: ol.machine,
            slices: 2,
            home_cached: false,
            dead_slice: None,
            rate_per_s: ol.rate_per_s,
            arrivals: ol.arrivals,
            ops: ol.ops,
            cached_client: ol.cached,
            hop_think: ol.hop_think,
            kvs_engines: ol.kvs_engines,
            seed: ol.seed,
            nodes: 1,
            migrate: false,
            threshold: 8,
            detect: Duration::from_us(40),
            kill: None,
            reconfig: Vec::new(),
        }
    }
}

impl SystemSpec {
    // -- presets ------------------------------------------------------------

    /// The paper's memory-node appliance: one directory slice, no
    /// caches anywhere, streaming client.
    pub fn memory_node() -> SystemSpec {
        SystemSpec { slices: 1, ..SystemSpec::default() }
    }

    /// A cached sliced directory: `n` slices sharing the machine's
    /// home-cache budget.
    pub fn dcs_cached(n: usize) -> SystemSpec {
        SystemSpec { slices: n, home_cached: true, ..SystemSpec::default() }
    }

    /// An `n`-node coherence fabric of default cells.
    pub fn fabric(n: u8) -> SystemSpec {
        SystemSpec { nodes: n, ..SystemSpec::default() }
    }

    /// Wrap an existing openloop config + slice count as a spec — the
    /// bridge hosts use to seed the control plane's "current shape"
    /// from their legacy constructor arguments.
    pub fn of_openloop(cfg: OpenLoopConfig, slices: usize) -> SystemSpec {
        SystemSpec {
            machine: cfg.machine,
            slices,
            home_cached: cfg.home_cached,
            rate_per_s: cfg.rate_per_s,
            arrivals: cfg.arrivals,
            ops: cfg.ops,
            cached_client: cfg.cached,
            hop_think: cfg.hop_think,
            kvs_engines: cfg.kvs_engines,
            seed: cfg.seed,
            ..SystemSpec::default()
        }
    }

    // -- derived plane configs ----------------------------------------------

    pub fn openloop_config(&self) -> OpenLoopConfig {
        OpenLoopConfig {
            rate_per_s: self.rate_per_s,
            arrivals: self.arrivals,
            ops: self.ops,
            cached: self.cached_client,
            home_cached: self.home_cached,
            hop_think: self.hop_think,
            kvs_engines: self.kvs_engines,
            seed: self.seed,
            machine: self.machine,
        }
    }

    pub fn dcs_config(&self) -> DcsConfig {
        let base = if self.home_cached {
            self.machine.dcs_cached_config(self.slices)
        } else {
            self.machine.dcs_config(self.slices)
        };
        base.with_dead_slice(self.dead_slice)
    }

    pub fn fabric_config(&self) -> FabricConfig {
        FabricConfig {
            nodes: self.nodes,
            migrate: self.migrate,
            threshold: self.threshold,
            slices: self.slices,
            kill: self.kill,
            detect: self.detect,
            abort_inject: false,
            ol: self.openloop_config(),
        }
    }

    // -- validation ---------------------------------------------------------

    /// Whole-spec validation, including a shape-tracking walk of the
    /// reconfig script: each scripted transition is checked against
    /// the shape the *preceding* transitions leave behind.
    pub fn validate(&self) -> Result<(), String> {
        if self.slices == 0 {
            return Err("need at least one directory slice".into());
        }
        if self.ops == 0 {
            return Err("need at least one arrival".into());
        }
        if !(self.rate_per_s > 0.0) {
            return Err(format!("offered rate must be positive, got {}", self.rate_per_s));
        }
        if self.kvs_engines == 0 {
            return Err("need at least one KVS engine".into());
        }
        if self.nodes == 0 {
            return Err("need at least one node".into());
        }
        if let Some(k) = &self.kill {
            if k.node as usize >= self.nodes as usize {
                return Err(format!("--kill node {} out of range (nodes {})", k.node, self.nodes));
            }
        }
        if self.nodes > 1 && !self.reconfig.is_empty() {
            return Err("live reconfiguration is single-cell for now (nodes must be 1)".into());
        }

        // shape-tracking walk of the reconfig script
        let mut cur_slices = self.slices;
        let mut cur_dead = self.dead_slice;
        let mut cur_cache =
            if self.home_cached { self.machine.home_cache_bytes } else { 0 };
        let ways = self.machine.home_cache_ways;
        let check_cache = |bytes: usize, slices: usize| -> Result<(), String> {
            if bytes > 0 && DcsConfig::max_cached_slices(bytes, ways) < slices {
                return Err(format!(
                    "home-cache budget {bytes}B is too small for {slices} cached slices"
                ));
            }
            Ok(())
        };
        check_cache(cur_cache, cur_slices).map_err(|e| format!("initial shape: {e}"))?;
        if let Some(d) = cur_dead {
            if cur_slices < 2 || d >= cur_slices {
                return Err(format!("dead slice {d} out of range ({cur_slices} slices)"));
            }
        }
        let mut sorted: Vec<&ReconfigEvent> = self.reconfig.iter().collect();
        sorted.sort_by_key(|e| e.at);
        for ev in sorted {
            let at = ev.at.ps() / 1_000_000;
            match ev.kind {
                ReconfigKind::Reslice(n) => {
                    if n == 0 {
                        return Err(format!("reslice target must be >= 1 (at {at}us)"));
                    }
                    if cur_dead.is_some() {
                        return Err(format!(
                            "reslice at {at}us while a slice is drained (rejoin first)"
                        ));
                    }
                    check_cache(cur_cache, n)
                        .map_err(|e| format!("reslice at {at}us: {e}"))?;
                    cur_slices = n;
                }
                ReconfigKind::CacheResize(b) => {
                    check_cache(b, cur_slices)
                        .map_err(|e| format!("cache resize at {at}us: {e}"))?;
                    cur_cache = b;
                }
                ReconfigKind::RelSwap(_) => {} // no-op on an unreliable link, by design
                ReconfigKind::Drain(d) => {
                    if cur_dead.is_some() {
                        return Err(format!("drain at {at}us with a slice already drained"));
                    }
                    if cur_slices < 2 {
                        return Err(format!("drain at {at}us would drain the only slice"));
                    }
                    if d >= cur_slices {
                        return Err(format!(
                            "drain target {d} out of range at {at}us ({cur_slices} slices)"
                        ));
                    }
                    cur_dead = Some(d);
                }
                ReconfigKind::Rejoin => {
                    if cur_dead.is_none() {
                        return Err(format!("rejoin at {at}us with no slice drained"));
                    }
                    cur_dead = None;
                }
            }
        }
        Ok(())
    }

    // -- CLI metadata -------------------------------------------------------

    /// Apply one CLI flag through the metadata table. `None` = the
    /// flag is not a spec field (the caller handles it); `Some(res)` =
    /// it is, with the parse outcome.
    pub fn apply_flag(&mut self, flag: &str, value: &str) -> Option<Result<(), String>> {
        SystemSpec::FIELDS.iter().find(|f| f.flag == flag).map(|f| (f.apply)(self, value))
    }

    /// Flags in [`SystemSpec::FIELDS`] that take a value (the CLI
    /// needs to know whether to consume the next argv token).
    pub fn flag_takes_value(flag: &str) -> Option<bool> {
        SystemSpec::FIELDS.iter().find(|f| f.flag == flag).map(|f| f.value.is_some())
    }

    /// One metadata row per shared CLI flag: spelling, metavar, help,
    /// and the parse-and-apply function. Every `eci bench` subcommand
    /// resolves these flags through this table, so `--slices`,
    /// `--rate`, `--seed` (and friends) parse identically everywhere.
    pub const FIELDS: &'static [FieldMeta] = &[
        FieldMeta {
            flag: "--slices",
            value: Some("N"),
            help: "directory slices per node",
            apply: |s, v| {
                s.slices = parse_usize(v, "--slices")?;
                Ok(())
            },
        },
        FieldMeta {
            flag: "--rate",
            value: Some("OPS_PER_S"),
            help: "offered arrival rate (accepts 4e6, 4M, 500k)",
            apply: |s, v| {
                s.rate_per_s = parse_rate(v)?;
                Ok(())
            },
        },
        FieldMeta {
            flag: "--ops",
            value: Some("N"),
            help: "total arrivals to generate",
            apply: |s, v| {
                s.ops = parse_u64(v, "--ops")?;
                Ok(())
            },
        },
        FieldMeta {
            flag: "--seed",
            value: Some("SEED"),
            help: "master RNG seed (decimal or 0x hex)",
            apply: |s, v| {
                s.seed = parse_seed(v)?;
                Ok(())
            },
        },
        FieldMeta {
            flag: "--nodes",
            value: Some("N"),
            help: "fabric width (1 = single cell)",
            apply: |s, v| {
                let n = parse_usize(v, "--nodes")?;
                s.nodes = u8::try_from(n).map_err(|_| format!("--nodes {n} too large"))?;
                Ok(())
            },
        },
        FieldMeta {
            flag: "--cached",
            value: None,
            help: "caching client (default: streaming)",
            apply: |s, _| {
                s.cached_client = true;
                Ok(())
            },
        },
        FieldMeta {
            flag: "--home-cached",
            value: None,
            help: "slices carry partitions of the home-cache budget",
            apply: |s, _| {
                s.home_cached = true;
                Ok(())
            },
        },
        FieldMeta {
            flag: "--deterministic",
            value: None,
            help: "deterministic arrivals (default: Poisson)",
            apply: |s, _| {
                s.arrivals = ArrivalKind::Deterministic;
                Ok(())
            },
        },
        FieldMeta {
            flag: "--kvs",
            value: Some("N"),
            help: "KVS engine-pool size",
            apply: |s, v| {
                s.kvs_engines = parse_usize(v, "--kvs")?;
                Ok(())
            },
        },
        FieldMeta {
            flag: "--migrate",
            value: None,
            help: "threshold-based home migration (fabric)",
            apply: |s, _| {
                s.migrate = true;
                Ok(())
            },
        },
        FieldMeta {
            flag: "--threshold",
            value: Some("N"),
            help: "remote requests before a line migrates",
            apply: |s, v| {
                s.threshold = parse_usize(v, "--threshold")? as u32;
                Ok(())
            },
        },
        FieldMeta {
            flag: "--kill",
            value: Some("NODE@US"),
            help: "scripted whole-node failure (fabric)",
            apply: |s, v| {
                s.kill = Some(parse_kill(v)?);
                Ok(())
            },
        },
        FieldMeta {
            flag: "--reconfig",
            value: Some("KIND[:ARG]@US"),
            help: "scripted live reconfiguration (repeatable; \
                   reslice:4@200us, cache:64k@50us, relmode:sr@300us, \
                   drain:1@120us, rejoin@240us)",
            apply: |s, v| {
                s.reconfig.extend(ReconfigEvent::parse_list(v)?);
                Ok(())
            },
        },
    ];
}

impl From<&SystemSpec> for OpenLoopConfig {
    fn from(s: &SystemSpec) -> OpenLoopConfig {
        s.openloop_config()
    }
}

impl From<&SystemSpec> for DcsConfig {
    fn from(s: &SystemSpec) -> DcsConfig {
        s.dcs_config()
    }
}

impl From<&SystemSpec> for FabricConfig {
    fn from(s: &SystemSpec) -> FabricConfig {
        s.fabric_config()
    }
}

/// One shared CLI flag: spelling, metavar (None = bare boolean), help
/// line, and the parse-and-apply function.
pub struct FieldMeta {
    pub flag: &'static str,
    pub value: Option<&'static str>,
    pub help: &'static str,
    pub apply: fn(&mut SystemSpec, &str) -> Result<(), String>,
}

// -- shared scalar parsers (the single home of each spelling) ---------------

fn parse_usize(v: &str, flag: &str) -> Result<usize, String> {
    v.parse().map_err(|_| format!("{flag} wants a non-negative integer, got `{v}`"))
}

fn parse_u64(v: &str, flag: &str) -> Result<u64, String> {
    v.parse().map_err(|_| format!("{flag} wants a non-negative integer, got `{v}`"))
}

/// Seeds accept decimal or `0x` hex.
pub fn parse_seed(v: &str) -> Result<u64, String> {
    let r = match v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => v.parse(),
    };
    r.map_err(|_| format!("--seed wants decimal or 0x hex, got `{v}`"))
}

/// Rates accept plain/scientific floats plus `k`/`M`/`G` suffixes.
pub fn parse_rate(v: &str) -> Result<f64, String> {
    let (digits, mul) = match v.as_bytes().last() {
        Some(b'k') | Some(b'K') => (&v[..v.len() - 1], 1e3),
        Some(b'm') | Some(b'M') => (&v[..v.len() - 1], 1e6),
        Some(b'g') | Some(b'G') => (&v[..v.len() - 1], 1e9),
        _ => (v, 1.0),
    };
    let r: f64 =
        digits.parse().map_err(|_| format!("--rate wants a rate (4e6, 4M, 500k), got `{v}`"))?;
    if !(r > 0.0) {
        return Err(format!("--rate must be positive, got `{v}`"));
    }
    Ok(r * mul)
}

/// `--kill NODE@US`.
pub fn parse_kill(v: &str) -> Result<KillSpec, String> {
    let (node, at) =
        v.split_once('@').ok_or_else(|| format!("--kill wants NODE@US, got `{v}`"))?;
    let node: u8 = node.parse().map_err(|_| format!("bad --kill node `{node}`"))?;
    let at = at.strip_suffix("us").unwrap_or(at);
    let us: u64 = at.parse().map_err(|_| format!("bad --kill time `{at}`"))?;
    Ok(KillSpec { node, at: Duration::from_us(us) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_presets_validate() {
        SystemSpec::default().validate().unwrap();
        SystemSpec::memory_node().validate().unwrap();
        SystemSpec::dcs_cached(4).validate().unwrap();
        SystemSpec::fabric(3).validate().unwrap();
        assert_eq!(SystemSpec::memory_node().slices, 1);
        assert!(SystemSpec::dcs_cached(4).home_cached);
        assert_eq!(SystemSpec::fabric(3).nodes, 3);
    }

    #[test]
    fn derived_configs_mirror_the_spec() {
        let mut s = SystemSpec::dcs_cached(4);
        s.rate_per_s = 7e6;
        s.ops = 123;
        s.seed = 0xBEEF;
        let ol: OpenLoopConfig = (&s).into();
        assert_eq!(ol.rate_per_s, 7e6);
        assert_eq!(ol.ops, 123);
        assert_eq!(ol.seed, 0xBEEF);
        assert!(ol.home_cached);
        let d: DcsConfig = (&s).into();
        assert_eq!(d.slices, 4);
        assert!(d.home_cached());
        assert_eq!(d.dead_slice, None);
        let f: FabricConfig = (&s).into();
        assert_eq!(f.slices, 4);
        assert_eq!(f.ol.ops, 123);

        s.dead_slice = Some(1);
        assert_eq!(s.dcs_config().dead_slice, Some(1));
    }

    #[test]
    fn of_openloop_round_trips() {
        let mut cfg = OpenLoopConfig::default();
        cfg.rate_per_s = 9e6;
        cfg.cached = true;
        let s = SystemSpec::of_openloop(cfg, 3);
        assert_eq!(s.slices, 3);
        assert!(s.cached_client);
        let back = s.openloop_config();
        assert_eq!(back.rate_per_s, cfg.rate_per_s);
        assert_eq!(back.ops, cfg.ops);
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.cached, cfg.cached);
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        let bad = |f: fn(&mut SystemSpec)| {
            let mut s = SystemSpec::default();
            f(&mut s);
            s.validate().unwrap_err()
        };
        assert!(bad(|s| s.slices = 0).contains("slice"));
        assert!(bad(|s| s.ops = 0).contains("arrival"));
        assert!(bad(|s| s.rate_per_s = 0.0).contains("rate"));
        assert!(bad(|s| s.kvs_engines = 0).contains("KVS"));
        assert!(bad(|s| s.nodes = 0).contains("node"));
        assert!(bad(|s| s.dead_slice = Some(5)).contains("out of range"));
        assert!(bad(|s| {
            s.nodes = 2;
            s.kill = Some(KillSpec { node: 2, at: Duration::from_us(1) });
        })
        .contains("out of range"));
    }

    #[test]
    fn validate_walks_the_reconfig_script_with_shape_tracking() {
        let script = |specs: &[&str]| -> Result<(), String> {
            let mut s = SystemSpec::default();
            for p in specs {
                s.reconfig.push(ReconfigEvent::parse(p).unwrap());
            }
            s.validate()
        };
        script(&["reslice:4@200us", "rejoin@400us"]).unwrap_err(); // rejoin w/o drain
        script(&["drain:1@100us", "drain:0@200us"]).unwrap_err(); // double drain
        script(&["drain:1@100us", "reslice:4@200us"]).unwrap_err(); // reslice while drained
        script(&["reslice:1@100us", "drain:0@200us"]).unwrap_err(); // drain the only slice
        script(&["drain:3@100us"]).unwrap_err(); // target out of range
        script(&["drain:1@100us", "rejoin@200us", "reslice:4@300us", "drain:3@400us"])
            .unwrap();
        // events validate in *time* order even if scripted out of order
        script(&["rejoin@400us", "drain:1@100us"]).unwrap();
    }

    #[test]
    fn validate_checks_cache_budget_against_slice_count() {
        let mut s = SystemSpec::dcs_cached(2);
        s.machine.home_cache_bytes = 1024; // 8 lines: too few for per-slice sets
        assert!(s.validate().is_err());

        let mut s = SystemSpec::default();
        s.reconfig.push(ReconfigEvent::parse("cache:1k@100us").unwrap());
        assert!(s.validate().is_err(), "scripted resize must respect the budget floor");
        let mut s = SystemSpec::default();
        s.reconfig.push(ReconfigEvent::parse("cache:0@100us").unwrap());
        s.validate().unwrap(); // 0 = caches off, always fine
    }

    #[test]
    fn apply_flag_covers_the_shared_surface() {
        let mut s = SystemSpec::default();
        s.apply_flag("--slices", "4").unwrap().unwrap();
        s.apply_flag("--rate", "2M").unwrap().unwrap();
        s.apply_flag("--ops", "5000").unwrap().unwrap();
        s.apply_flag("--seed", "0xAB").unwrap().unwrap();
        s.apply_flag("--cached", "").unwrap().unwrap();
        s.apply_flag("--home-cached", "").unwrap().unwrap();
        s.apply_flag("--deterministic", "").unwrap().unwrap();
        s.apply_flag("--reconfig", "reslice:4@200us").unwrap().unwrap();
        s.apply_flag("--reconfig", "rejoin@400us").unwrap().unwrap();
        assert_eq!(s.slices, 4);
        assert_eq!(s.rate_per_s, 2e6);
        assert_eq!(s.ops, 5000);
        assert_eq!(s.seed, 0xAB);
        assert!(s.cached_client && s.home_cached);
        assert_eq!(s.arrivals, ArrivalKind::Deterministic);
        assert_eq!(s.reconfig.len(), 2, "--reconfig is repeatable");

        assert!(s.apply_flag("--no-such-flag", "1").is_none());
        assert!(s.apply_flag("--slices", "wat").unwrap().is_err());
        assert_eq!(SystemSpec::flag_takes_value("--slices"), Some(true));
        assert_eq!(SystemSpec::flag_takes_value("--cached"), Some(false));
        assert_eq!(SystemSpec::flag_takes_value("--bogus"), None);
    }

    #[test]
    fn scalar_parsers_accept_the_documented_spellings() {
        assert_eq!(parse_seed("0xEC1").unwrap(), 0xEC1);
        assert_eq!(parse_seed("17").unwrap(), 17);
        assert!(parse_seed("xyz").is_err());
        assert_eq!(parse_rate("4e6").unwrap(), 4e6);
        assert_eq!(parse_rate("500k").unwrap(), 5e5);
        assert_eq!(parse_rate("2M").unwrap(), 2e6);
        assert!(parse_rate("-1").is_err());
        let k = parse_kill("1@250us").unwrap();
        assert_eq!(k.node, 1);
        assert_eq!(k.at, Duration::from_us(250));
        assert!(parse_kill("250us").is_err());
    }
}
