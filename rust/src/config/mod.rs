pub mod cli;
pub mod spec;

pub use spec::{FieldMeta, SystemSpec};
