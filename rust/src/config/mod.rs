pub mod cli;
