//! The `eci` command-line launcher (hand-rolled arg parsing — `clap` is
//! not available in the offline registry).
//!
//! ```text
//! eci resources                  print Table 2 + subsetting ablation
//! eci bench <table3|fig5|fig6|fig7|fig8|all>
//! eci check                      validate envelope + subsets, print report
//! eci trace-demo                 run a traffic capture through the
//!                                dissector and the online checker
//! ```
//! `ECI_SCALE={ci,default,paper}` controls workload sizes.

use crate::harness::{fig5, fig6, fig7, fig8, table2, table3, Scale};
use crate::proto::subset::{validate_with_workload, Subset};
use crate::proto::messages::CohOp;
use crate::runtime::Runtime;

pub fn main_entry() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let scale = Scale::from_env();
    match cmd {
        "resources" => {
            for t in table2::render() {
                println!("{}", t.to_markdown());
            }
        }
        "bench" => {
            let which = args.get(1).map(String::as_str).unwrap_or("all");
            run_bench(which, scale);
        }
        "check" => check(),
        "trace-demo" => crate::trace::demo::run_demo(),
        _ => {
            eprintln!(
                "usage: eci <resources|bench [table3|fig5|fig6|fig7|fig8|all]|check|trace-demo>\n\
                 env: ECI_SCALE={{ci,default,paper}} (current: {scale:?})"
            );
        }
    }
}

fn run_bench(which: &str, scale: Scale) {
    let needs_rt = matches!(which, "fig5" | "fig6" | "fig7" | "all");
    let mut rt = if needs_rt {
        Some(Runtime::load_default().expect("artifacts missing — run `make artifacts`"))
    } else {
        None
    };
    if matches!(which, "table3" | "all") {
        println!("{}", table3::render(&table3::run(scale)).to_markdown());
    }
    if matches!(which, "fig5" | "all") {
        let f = fig5::run(rt.as_mut().unwrap(), scale).expect("fig5");
        println!("{}", fig5::render(&f).to_markdown());
    }
    if matches!(which, "fig6" | "all") {
        let f = fig6::run(rt.as_mut().unwrap(), scale).expect("fig6");
        println!("{}", fig6::render(&f).to_markdown());
    }
    if matches!(which, "fig7" | "all") {
        let f = fig7::run(rt.as_mut().unwrap(), scale).expect("fig7");
        println!("{}", fig7::render(&f).to_markdown());
    }
    if matches!(which, "fig8" | "all") {
        println!("{}", fig8::render(&fig8::run(scale)).to_markdown());
    }
}

fn check() {
    use crate::proto::envelope::{check_envelope, check_recommendations};
    use crate::proto::transitions::reference_transitions;
    let table = reference_transitions();
    let v = check_envelope(&table);
    println!("envelope: {} violations", v.len());
    for x in &v {
        println!("  {x}");
    }
    for note in check_recommendations(&table) {
        println!("  note: {note}");
    }
    let full = Subset::full_symmetric();
    for s in [
        Subset::full_symmetric(),
        Subset::asymmetric_accelerator(),
        Subset::cpu_initiator_readonly(),
        Subset::stateless_readonly(),
    ] {
        // the read-only subsets are only valid under the read-only
        // workload guarantee (R5's escape hatch, §3.3); the stateless home
        // additionally never issues fwds itself
        let workload: &[CohOp] = match s.name {
            "stateless-readonly" => &[CohOp::ReadShared, CohOp::VolDowngradeI],
            "cpu-initiator-readonly" => {
                &[CohOp::ReadShared, CohOp::VolDowngradeI, CohOp::FwdDowngradeI]
            }
            _ => &CohOp::ALL,
        };
        let v = validate_with_workload(&s, &full, workload);
        println!(
            "subset {:<24} home-states={} violations={}",
            s.name,
            s.home_state_count(),
            v.len()
        );
        for x in &v {
            println!("  {x}");
        }
    }
}
