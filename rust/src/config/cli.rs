//! The `eci` command-line launcher (hand-rolled arg parsing — `clap` is
//! not available in the offline registry).
//!
//! ```text
//! eci resources                  print Table 2 + subsetting ablation
//! eci bench <table3|fig5|fig6|fig7|fig8|dcs|workload|faults|retx|fabric|reconfig|selfperf|all> [flags]
//! eci check                      validate envelope + subsets, print report
//! eci trace-demo                 run a traffic capture through the
//!                                dissector and the online checker
//! ```
//! `ECI_SCALE={ci,default,paper}` controls workload sizes.
//!
//! The `dcs` bench (closed-loop directory-slice throughput sweep) takes
//! flags so slice counts and the load-generator mix can be swept from
//! the command line:
//!
//! ```text
//! eci bench dcs [--slices 1,2,4,8] [--cached-slices 2,4] [--batch 4]
//!               [--clients 32] [--ops 20000] [--mix 60:20:20]
//!               [--hops 4] [--theta 0.99]
//! ```
//!
//! `--cached-slices` adds *cached* sweep points (slice-local home
//! caches, the symmetric configuration); `--batch` sets the
//! framed-ingress batch size; `--theta` skews the line popularity.
//!
//! The `workload` bench (open-loop, scenario-driven latency-vs-load
//! sweep with credit-accurate link admission — `harness::fig_loadcurve`):
//!
//! ```text
//! eci bench workload [--scenario uniform|hot-kvs|scan|chase|tenants]
//!                    [--slices 1,2,4,8] [--cached-slices 2,4]
//!                    [--batch 4] [--rate 2e6,8e6,...] [--theta 0.99]
//!                    [--classes hot-kvs:2,scan:1] [--ops 12000]
//!                    [--arrivals poisson|fixed] [--cached] [--seed N]
//! ```
//!
//! The `faults` bench (goodput and tail latency vs bit-error rate over
//! the reliable lossy link — `harness::fig_goodput`):
//!
//! ```text
//! eci bench faults [--ber 1e-6,1e-4,1e-3] [--drop 0.02] [--reorder 0.02]
//!                  [--burst 8] [--seed 7] [--slices 1,4]
//!                  [--cached-slices 2] [--rate 2e6] [--ops 1200]
//!                  [--scenario scan] [--mode gbn|sr] [--adaptive-rto]
//! ```
//!
//! The `retx` bench (replay bandwidth vs retransmission discipline:
//! go-back-N vs selective repeat vs selective repeat + adaptive RTO —
//! `harness::fig_retx`; the discipline grid is the sweep, so `--mode`
//! belongs to `faults`, not here):
//!
//! ```text
//! eci bench retx [--ber 1e-4,1e-3] [--drop 0.02] [--reorder 0.02]
//!                [--burst 8] [--seed 7] [--slices 4] [--rate 2e6]
//!                [--ops 1200] [--scenario scan]
//! ```
//!
//! The `fabric` bench (multi-node scale-out: aggregate goodput and
//! tail latency vs node count with home migration on/off —
//! `harness::fig_fabric`; `--rate` is *per node*, `--ops` fabric-wide):
//!
//! ```text
//! eci bench fabric [--nodes 1,2,4] [--migrate on|off|both]
//!                  [--threshold 8] [--slices 2] [--rate 2e6]
//!                  [--ops 1600] [--scenario hot-kvs] [--theta 0.99]
//!                  [--kill 1@200] [--detect-us 40]
//!                  [--seed 7] [--json]
//! ```
//!
//! `--kill N@US` scripts a whole-node failure: node N goes dark US
//! microseconds into each sweep point (arrivals auto-extend so the kill
//! lands mid-run), survivors re-home its lines and replay its in-flight
//! requests, and a second table reports detection latency, goodput-dip
//! depth and recovery duration. `--detect-us` bounds the failure
//! detector's watchdog (default 40).
//!
//! The `reconfig` bench (live reconfiguration with traffic in flight:
//! p99 dip depth and duration per scripted transition —
//! `harness::fig_reconfig`; see `rust/DESIGN.md` §ctrl). `--scenario`,
//! `--theta` and `--json` are bench-local; every other flag resolves
//! through `SystemSpec::FIELDS`, the shared field-metadata table, so
//! `--slices`, `--rate`, `--ops`, `--seed`, `--reconfig` (and friends)
//! parse identically everywhere and a stray flag is an error, never
//! silently ignored:
//!
//! ```text
//! eci bench reconfig [--reconfig reslice:4@200us,cache:64k@400us]
//!                    [--reconfig relmode:sr@600us]   (repeatable)
//!                    [--slices 2] [--home-cached] [--rate 6e6]
//!                    [--ops 12000] [--scenario scan] [--theta 0.99]
//!                    [--seed N] [--json]
//! ```
//!
//! With no `--reconfig` script it runs the default transition family
//! (re-slice 2→4, drain + rejoin, rel-mode swap, cache resize) spaced
//! across the run. The script is shape-validated before anything runs
//! (`SystemSpec::validate` walks it transition by transition).
//!
//! The `selfperf` bench (the simulator's own host throughput on pinned
//! configurations — `harness::selfperf`; `BENCH_6.json` is the
//! committed baseline, `--check` gates CI on it):
//!
//! ```text
//! eci bench selfperf [--check BENCH_6.json] [--record BENCH_6.json]
//!                    [--tolerance 0.25] [--json]
//! ```
//!
//! Observability (`rust/DESIGN.md` §obs): `dcs`, `workload`, `faults`
//! and `retx` all take a bare `--json` flag that emits each result
//! table as JSON alongside the markdown. `workload` additionally takes
//! `--spans` (print the per-stage latency waterfall from one observed
//! run per slice count) and `--obs-out <path>` (write telemetry
//! JSON-lines from the observed run).
//!
//! Every stochastic bench takes a global `--seed` (Poisson arrivals,
//! Zipf draws, fault injection all derive from it, so any run is
//! reproducible from the command line). Defaults: `dcs` 0xDC5,
//! `workload`/`faults`/`retx`/`fabric` 0x0C3A.
//!
//! Flags are only accepted by the bench they belong to; every other
//! bench id rejects stray arguments loudly (a typo must not green-wash
//! a CI smoke step).

use crate::config::SystemSpec;
use crate::dcs::loadgen::{LoadGenConfig, MixConfig};
use crate::fabric::{FabricConfig, KillSpec};
use crate::harness::fig_goodput::{self, FaultKnobs};
use crate::harness::{
    fig5, fig6, fig7, fig8, fig_fabric, fig_loadcurve, fig_reconfig, fig_retx, fig_throughput,
    selfperf, table2, table3, Scale,
};
use crate::transport::{RelConfig, RelMode};
use crate::proto::messages::CohOp;
use crate::proto::subset::{validate_with_workload, Subset};
use crate::runtime::Runtime;
use crate::sim::time::Duration;
use crate::workload::{ArrivalKind, OpenLoopConfig, Scenario, TrafficClass};

pub fn main_entry() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let scale = Scale::from_env();
    match cmd {
        "resources" => {
            for t in table2::render() {
                println!("{}", t.to_markdown());
            }
        }
        "bench" => {
            let which = args.get(1).map(String::as_str).unwrap_or("all");
            run_bench(which, scale, &args[2.min(args.len())..]);
        }
        "check" => check(),
        "trace-demo" => crate::trace::demo::run_demo(),
        _ => {
            eprintln!(
                "usage: eci <resources|bench [table3|fig5|fig6|fig7|fig8|dcs|workload|faults|retx|fabric|reconfig|selfperf|all]|check|trace-demo>\n\
                 dcs flags:      --slices 1,2,4,8 --cached-slices 2,4 --batch 4 --clients 32\n\
                                 --ops 20000 --mix 60:20:20 --hops 4 --theta 0.99 --seed N --json\n\
                 workload flags: --scenario {scenarios} --slices 1,2,4,8 --cached-slices 2,4\n\
                                 --batch 4 --rate 2e6,8e6 --theta 0.99 --classes hot-kvs:2,scan:1\n\
                                 --ops 12000 --arrivals poisson|fixed --cached --seed N --json\n\
                                 --spans --obs-out run.jsonl --trace-out run.trace.json\n\
                 faults flags:   --ber 1e-6,1e-4,1e-3 --drop 0.02 --reorder 0.02 --burst 8\n\
                                 --seed 7 --slices 1,4 --cached-slices 2 --rate 2e6\n\
                                 --ops 1200 --scenario {scenarios} --mode gbn|sr --adaptive-rto --json\n\
                 retx flags:     --ber 1e-4,1e-3 --drop 0.02 --reorder 0.02 --burst 8 --seed 7\n\
                                 --slices 4 --rate 2e6 --ops 1200 --scenario {scenarios} --json\n\
                 fabric flags:   --nodes 1,2,4 --migrate on|off|both --threshold 8 --slices 2\n\
                                 --rate 2e6 --ops 1600 --scenario {scenarios} --theta 0.99 --seed 7 --json\n\
                                 --kill 1@200 --detect-us 500 --spans --obs-out fab.jsonl\n\
                                 --trace-out fab.trace.json --flight-dump post.json\n\
                 reconfig flags: --reconfig reslice:4@200us,cache:64k@400us (repeatable)\n\
                                 --slices 2 --home-cached --rate 6e6 --ops 12000\n\
                                 --scenario {scenarios} --theta 0.99 --seed N --json\n\
                 selfperf flags: --check BENCH_6.json --record BENCH_6.json --tolerance 0.25 --json\n\
                 seeds: every stochastic bench takes --seed (defaults: dcs 0xDC5, workload/faults/retx/fabric 0x0C3A)\n\
                 env: ECI_SCALE={{ci,default,paper}} (current: {scale:?}; selfperf ignores it)",
                scenarios = Scenario::preset_names().join("|")
            );
        }
    }
}

/// Parsed `eci bench dcs` flags: slice sweep + load-generator shape.
#[derive(Clone, Debug, PartialEq)]
pub struct DcsArgs {
    pub slices: Vec<usize>,
    /// Slice counts to additionally run with slice-local home caches
    /// (the symmetric configuration).
    pub cached_slices: Vec<usize>,
    /// Framed-ingress batch size (1 = batching off).
    pub batch: usize,
    /// `--json`: emit the table as JSON alongside the markdown.
    pub json: bool,
    pub cfg: LoadGenConfig,
}

impl DcsArgs {
    pub fn defaults(scale: Scale) -> DcsArgs {
        DcsArgs {
            slices: fig_throughput::SLICE_SWEEP.to_vec(),
            cached_slices: Vec::new(),
            batch: 1,
            json: false,
            cfg: LoadGenConfig { ops: fig_throughput::ops_for(scale), ..Default::default() },
        }
    }

    /// Parse `--flag value` pairs (`--json` is a bare flag); unknown
    /// flags are errors.
    pub fn parse(scale: Scale, args: &[String]) -> Result<DcsArgs, String> {
        let mut out = DcsArgs::defaults(scale);
        let mut it = args.iter();
        while let Some(flag) = it.next() {
            if flag == "--json" {
                out.json = true;
                continue;
            }
            let val = it
                .next()
                .ok_or_else(|| format!("{flag} needs a value"))?;
            match flag.as_str() {
                "--slices" => {
                    out.slices = parse_usize_list(val)?;
                }
                "--cached-slices" => {
                    out.cached_slices = parse_usize_list(val)?;
                }
                "--batch" => {
                    let b: usize = val.parse().map_err(|_| format!("bad batch size {val:?}"))?;
                    if b == 0 {
                        return Err("--batch must be >= 1".into());
                    }
                    out.batch = b;
                }
                "--theta" => {
                    let t: f64 = val.parse().map_err(|_| format!("bad theta {val:?}"))?;
                    if !(t >= 0.0 && t.is_finite()) {
                        return Err(format!("theta must be >= 0, got {val:?}"));
                    }
                    out.cfg.theta = t;
                }
                "--clients" => {
                    out.cfg.clients =
                        val.parse().map_err(|_| format!("bad client count {val:?}"))?;
                }
                "--ops" => {
                    out.cfg.ops = val.parse().map_err(|_| format!("bad op count {val:?}"))?;
                }
                "--mix" => {
                    // weights are ratios; cap them so the u32 weight sum
                    // can never overflow in MixConfig::total()
                    const MAX_WEIGHT: u32 = 1_000_000;
                    let parts: Vec<u32> = val
                        .split(':')
                        .map(|p| p.trim().parse::<u32>().map_err(|_| format!("bad mix {val:?}")))
                        .collect::<Result<Vec<_>, _>>()?;
                    let &[r, w, c] = parts.as_slice() else {
                        return Err(format!("--mix wants reads:writes:chases, got {val:?}"));
                    };
                    if r == 0 && w == 0 && c == 0 {
                        return Err("--mix must not be all zero".into());
                    }
                    if r.max(w).max(c) > MAX_WEIGHT {
                        return Err(format!("--mix weights must be <= {MAX_WEIGHT}"));
                    }
                    out.cfg.mix = MixConfig { reads: r, writes: w, chases: c, ..out.cfg.mix };
                }
                "--hops" => {
                    out.cfg.mix.chase_hops =
                        val.parse().map_err(|_| format!("bad hop count {val:?}"))?;
                }
                "--seed" => {
                    out.cfg.seed = parse_seed(val)?;
                }
                other => return Err(format!("unknown dcs flag {other:?}")),
            }
        }
        if out.cfg.clients == 0 {
            return Err("--clients must be >= 1".into());
        }
        if out.cfg.ops == 0 {
            return Err("--ops must be >= 1".into());
        }
        check_cached_slices(
            &out.cached_slices,
            crate::dcs::DEFAULT_HOME_CACHE_BYTES,
            crate::dcs::DEFAULT_HOME_CACHE_WAYS,
        )?;
        Ok(out)
    }
}

/// Reject `--cached-slices` counts the home-cache budget cannot be split
/// across (each slice partition needs at least one full set of ways) —
/// an oversized count must fail like every other malformed flag, not
/// panic mid-sweep.
fn check_cached_slices(cached: &[usize], budget_bytes: usize, ways: usize) -> Result<(), String> {
    let max = crate::dcs::DcsConfig::max_cached_slices(budget_bytes, ways);
    for &n in cached {
        if n > max {
            return Err(format!(
                "--cached-slices {n} cannot split the {budget_bytes}-byte home-cache \
                 budget ({ways}-way): at most {max} slices"
            ));
        }
    }
    Ok(())
}

/// Parsed `eci bench workload` flags: scenario shape + sweep axes.
#[derive(Clone, Debug)]
pub struct WorkloadArgs {
    pub slices: Vec<usize>,
    /// Slice counts to additionally sweep with slice-local home caches.
    pub cached_slices: Vec<usize>,
    pub scenario: String,
    pub theta: f64,
    /// `--classes name:weight,...` overrides the named scenario.
    pub classes: Option<Vec<(String, u32)>>,
    /// Explicit offered-rate grid (ops/s); default derives from the
    /// slice-pipeline capacity.
    pub rates: Option<Vec<f64>>,
    /// `--spans`: run one *observed* point per slice count (at the
    /// first rate of the grid) and print the latency waterfall instead
    /// of sweeping the whole grid.
    pub spans: bool,
    /// `--obs-out <path>`: write telemetry JSONL (first slice count).
    pub obs_out: Option<String>,
    /// `--trace-out <path>`: write the observed run as Chrome
    /// trace-event JSON (first slice count).
    pub trace_out: Option<String>,
    /// `--json`: emit tables as JSON alongside the markdown.
    pub json: bool,
    pub cfg: OpenLoopConfig,
}

impl WorkloadArgs {
    pub fn defaults(scale: Scale) -> WorkloadArgs {
        WorkloadArgs {
            slices: fig_loadcurve::SLICE_SWEEP.to_vec(),
            cached_slices: Vec::new(),
            scenario: "tenants".into(),
            theta: 0.99,
            classes: None,
            rates: None,
            spans: false,
            obs_out: None,
            trace_out: None,
            json: false,
            cfg: OpenLoopConfig { ops: fig_loadcurve::ops_for(scale), ..Default::default() },
        }
    }

    /// Parse `--flag value` pairs (`--cached`, `--spans` and `--json`
    /// are bare flags); unknown flags are errors.
    pub fn parse(scale: Scale, args: &[String]) -> Result<WorkloadArgs, String> {
        let mut out = WorkloadArgs::defaults(scale);
        let mut it = args.iter();
        while let Some(flag) = it.next() {
            if flag == "--cached" {
                out.cfg.cached = true;
                continue;
            }
            if flag == "--spans" {
                out.spans = true;
                continue;
            }
            if flag == "--json" {
                out.json = true;
                continue;
            }
            let val = it.next().ok_or_else(|| format!("{flag} needs a value"))?;
            match flag.as_str() {
                "--scenario" => {
                    out.scenario = check_scenario(val)?;
                }
                "--slices" => {
                    out.slices = parse_usize_list(val)?;
                }
                "--cached-slices" => {
                    out.cached_slices = parse_usize_list(val)?;
                }
                "--batch" => {
                    let b: usize = val.parse().map_err(|_| format!("bad batch size {val:?}"))?;
                    if b == 0 {
                        return Err("--batch must be >= 1".into());
                    }
                    out.cfg.machine.ingress_batch = b;
                }
                "--rate" => {
                    let rates = val
                        .split(',')
                        .map(|s| {
                            s.trim()
                                .parse::<f64>()
                                .map_err(|_| format!("bad rate {s:?}"))
                                .and_then(|r| {
                                    if r > 0.0 && r.is_finite() {
                                        Ok(r)
                                    } else {
                                        Err(format!("rate must be positive, got {s:?}"))
                                    }
                                })
                        })
                        .collect::<Result<Vec<_>, _>>()?;
                    if rates.is_empty() {
                        return Err("--rate needs at least one value".into());
                    }
                    out.rates = Some(rates);
                }
                "--theta" => {
                    let t: f64 = val.parse().map_err(|_| format!("bad theta {val:?}"))?;
                    if !(t >= 0.0 && t.is_finite()) {
                        return Err(format!("theta must be >= 0, got {val:?}"));
                    }
                    out.theta = t;
                }
                "--classes" => {
                    let mut classes = Vec::new();
                    for part in val.split(',') {
                        let part = part.trim();
                        let (name, w) = match part.split_once(':') {
                            Some((n, w)) => (
                                n.to_string(),
                                w.parse::<u32>().map_err(|_| format!("bad class weight {part:?}"))?,
                            ),
                            None => (part.to_string(), 1),
                        };
                        if w == 0 {
                            return Err(format!("class weight must be >= 1 in {part:?}"));
                        }
                        classes.push((name, w));
                    }
                    if classes.is_empty() {
                        return Err("--classes needs at least one class".into());
                    }
                    out.classes = Some(classes);
                }
                "--ops" => {
                    out.cfg.ops = val.parse().map_err(|_| format!("bad op count {val:?}"))?;
                }
                "--arrivals" => {
                    out.cfg.arrivals = ArrivalKind::parse(val)
                        .ok_or_else(|| format!("bad arrival process {val:?}"))?;
                }
                "--obs-out" => {
                    if val.is_empty() {
                        return Err("--obs-out needs a file path".into());
                    }
                    out.obs_out = Some(val.clone());
                }
                "--trace-out" => {
                    if val.is_empty() {
                        return Err("--trace-out needs a file path".into());
                    }
                    out.trace_out = Some(val.clone());
                }
                "--seed" => {
                    out.cfg.seed = parse_seed(val)?;
                }
                other => return Err(format!("unknown workload flag {other:?}")),
            }
        }
        if out.cfg.ops == 0 {
            return Err("--ops must be >= 1".into());
        }
        check_cached_slices(
            &out.cached_slices,
            out.cfg.machine.home_cache_bytes,
            out.cfg.machine.home_cache_ways,
        )?;
        Ok(out)
    }

    /// Materialize the scenario this invocation describes.
    pub fn scenario(&self, scale: Scale) -> Result<Scenario, String> {
        let base = fig_loadcurve::footprint_for(scale);
        match &self.classes {
            None => Scenario::preset(&self.scenario, base, self.theta)
                .ok_or_else(|| format!("unknown scenario {:?}", self.scenario)),
            Some(specs) => {
                let mut classes = Vec::new();
                for (name, w) in specs {
                    let c = TrafficClass::by_name(name, base, self.theta)
                        .ok_or_else(|| format!("unknown traffic class {name:?}"))?;
                    classes.push(c.with_weight(*w));
                }
                Ok(Scenario::new("custom", classes))
            }
        }
    }

    /// The offered-rate grid to sweep.
    pub fn rates(&self) -> Vec<f64> {
        match &self.rates {
            Some(r) => r.clone(),
            None => fig_loadcurve::default_rates(self.cfg.machine.home_proc),
        }
    }
}

/// Parsed `eci bench faults` flags: fault knobs + sweep axes for the
/// reliable-lossy-link goodput figure (`harness::fig_goodput`).
#[derive(Clone, Debug)]
pub struct FaultsArgs {
    pub slices: Vec<usize>,
    /// Slice counts to additionally sweep with slice-local home caches.
    pub cached_slices: Vec<usize>,
    pub scenario: String,
    /// Bit-error-rate grid (0 = clean baseline through the rel layer).
    pub bers: Vec<f64>,
    pub knobs: FaultKnobs,
    /// Fixed offered rate; default derives from the slice pipeline.
    pub rate: Option<f64>,
    /// `--json`: emit the table as JSON alongside the markdown.
    pub json: bool,
    pub cfg: OpenLoopConfig,
}

impl FaultsArgs {
    pub fn defaults(scale: Scale) -> FaultsArgs {
        FaultsArgs {
            slices: fig_goodput::SLICE_SWEEP.to_vec(),
            cached_slices: Vec::new(),
            scenario: "scan".into(),
            bers: fig_goodput::BER_SWEEP.to_vec(),
            knobs: FaultKnobs::default(),
            rate: None,
            json: false,
            cfg: OpenLoopConfig { ops: fig_goodput::ops_for(scale), ..Default::default() },
        }
    }

    /// Parse `--flag value` pairs (`--adaptive-rto` and `--json` are
    /// bare flags); unknown flags are errors.
    pub fn parse(scale: Scale, args: &[String]) -> Result<FaultsArgs, String> {
        let mut out = FaultsArgs::defaults(scale);
        let mut it = args.iter();
        while let Some(flag) = it.next() {
            if flag == "--adaptive-rto" {
                out.knobs.adaptive_rto = true;
                continue;
            }
            if flag == "--json" {
                out.json = true;
                continue;
            }
            let val = it.next().ok_or_else(|| format!("{flag} needs a value"))?;
            match flag.as_str() {
                "--mode" => {
                    out.knobs.mode = RelMode::parse(val)
                        .ok_or_else(|| format!("bad rel mode {val:?} (have: gbn, sr)"))?;
                }
                "--ber" => {
                    out.bers = parse_ber_list(val)?;
                }
                "--drop" => {
                    out.knobs.drop = parse_prob(val, "--drop")?;
                }
                "--reorder" => {
                    out.knobs.reorder = parse_prob(val, "--reorder")?;
                }
                "--burst" => {
                    out.knobs.burst_len = parse_burst(val)?;
                }
                "--seed" => {
                    let s = parse_seed(val)?;
                    // one seed reproduces the whole run: traffic draws
                    // and fault injection both derive from it
                    out.knobs.seed = s;
                    out.cfg.seed = s;
                }
                "--slices" => {
                    out.slices = parse_usize_list(val)?;
                }
                "--cached-slices" => {
                    out.cached_slices = parse_usize_list(val)?;
                }
                "--rate" => {
                    out.rate = Some(parse_rate_scalar(val)?);
                }
                "--ops" => {
                    out.cfg.ops = val.parse().map_err(|_| format!("bad op count {val:?}"))?;
                }
                "--scenario" => {
                    out.scenario = check_scenario(val)?;
                }
                other => return Err(format!("unknown faults flag {other:?}")),
            }
        }
        if out.cfg.ops == 0 {
            return Err("--ops must be >= 1".into());
        }
        check_cached_slices(
            &out.cached_slices,
            out.cfg.machine.home_cache_bytes,
            out.cfg.machine.home_cache_ways,
        )?;
        Ok(out)
    }

    /// The offered rate of the sweep.
    pub fn rate(&self) -> f64 {
        self.rate.unwrap_or_else(|| fig_goodput::default_rate(self.cfg.machine.home_proc))
    }
}

/// Parsed `eci bench retx` flags: fault knobs + sweep axes for the
/// retransmission-discipline ablation (`harness::fig_retx`). The
/// discipline grid (gbn, sr, sr+adaptive-rto) IS the figure, so there
/// is no `--mode` here — passing one fails loudly like any stray flag.
#[derive(Clone, Debug)]
pub struct RetxArgs {
    pub slices: Vec<usize>,
    pub scenario: String,
    /// Bit-error-rate grid (the disciplines only separate under loss,
    /// so unlike `faults` the default grid carries no clean point).
    pub bers: Vec<f64>,
    pub knobs: FaultKnobs,
    /// Fixed offered rate; default derives from the slice pipeline.
    pub rate: Option<f64>,
    /// `--json`: emit the table as JSON alongside the markdown.
    pub json: bool,
    pub cfg: OpenLoopConfig,
}

impl RetxArgs {
    pub fn defaults(scale: Scale) -> RetxArgs {
        RetxArgs {
            slices: fig_retx::SLICE_SWEEP.to_vec(),
            scenario: "scan".into(),
            bers: fig_retx::BER_SWEEP.to_vec(),
            knobs: FaultKnobs::default(),
            rate: None,
            json: false,
            cfg: OpenLoopConfig { ops: fig_retx::ops_for(scale), ..Default::default() },
        }
    }

    /// Parse `--flag value` pairs (`--json` is a bare flag); unknown
    /// flags are errors.
    pub fn parse(scale: Scale, args: &[String]) -> Result<RetxArgs, String> {
        let mut out = RetxArgs::defaults(scale);
        let mut it = args.iter();
        while let Some(flag) = it.next() {
            if flag == "--json" {
                out.json = true;
                continue;
            }
            let val = it.next().ok_or_else(|| format!("{flag} needs a value"))?;
            match flag.as_str() {
                "--ber" => {
                    out.bers = parse_ber_list(val)?;
                }
                "--drop" => {
                    out.knobs.drop = parse_prob(val, "--drop")?;
                }
                "--reorder" => {
                    out.knobs.reorder = parse_prob(val, "--reorder")?;
                }
                "--burst" => {
                    out.knobs.burst_len = parse_burst(val)?;
                }
                "--seed" => {
                    let s = parse_seed(val)?;
                    // one seed reproduces the whole run: traffic draws
                    // and fault injection both derive from it
                    out.knobs.seed = s;
                    out.cfg.seed = s;
                }
                "--slices" => {
                    out.slices = parse_usize_list(val)?;
                }
                "--rate" => {
                    out.rate = Some(parse_rate_scalar(val)?);
                }
                "--ops" => {
                    out.cfg.ops = val.parse().map_err(|_| format!("bad op count {val:?}"))?;
                }
                "--scenario" => {
                    out.scenario = check_scenario(val)?;
                }
                other => return Err(format!("unknown retx flag {other:?}")),
            }
        }
        if out.cfg.ops == 0 {
            return Err("--ops must be >= 1".into());
        }
        Ok(out)
    }

    /// The offered rate of the sweep.
    pub fn rate(&self) -> f64 {
        self.rate.unwrap_or_else(|| fig_goodput::default_rate(self.cfg.machine.home_proc))
    }
}

/// Parsed `eci bench fabric` flags: multi-node scale-out sweep
/// (`harness::fig_fabric`). `--rate` is the *per-node* offered rate
/// (default: node-saturating); `--ops` is the fabric-wide total.
#[derive(Clone, Debug)]
pub struct FabricArgs {
    /// Node counts to sweep.
    pub nodes: Vec<u8>,
    /// Migration settings to run each node count at.
    pub modes: Vec<bool>,
    /// Remote-access threshold before a line migrates.
    pub threshold: u32,
    /// Directory slices per node.
    pub slices: usize,
    pub scenario: String,
    pub theta: f64,
    /// Fixed per-node offered rate; default saturates one node.
    pub rate: Option<f64>,
    /// `--kill N@US`: node N goes dark US microseconds into each point.
    pub kill: Option<KillSpec>,
    /// `--detect-us`: failure-detector watchdog bound, µs.
    pub detect_us: Option<u64>,
    /// `--spans`: run observed points (one per node count, first
    /// migrate mode) and print local + remote latency waterfalls.
    pub spans: bool,
    /// `--obs-out <path>`: write telemetry JSONL (first node count).
    pub obs_out: Option<String>,
    /// `--trace-out <path>`: write the observed run as Chrome
    /// trace-event JSON (first node count).
    pub trace_out: Option<String>,
    /// `--flight-dump <path>`: attach the flight recorder and write its
    /// dumps (deadlock, `declare_dead`, end of run) here.
    pub flight_dump: Option<String>,
    /// `--json`: emit the table as JSON alongside the markdown.
    pub json: bool,
    pub cfg: OpenLoopConfig,
}

impl FabricArgs {
    pub fn defaults(scale: Scale) -> FabricArgs {
        let base = FabricConfig::default();
        FabricArgs {
            nodes: fig_fabric::node_sweep(scale),
            modes: vec![false, true],
            threshold: base.threshold,
            slices: base.slices,
            scenario: "hot-kvs".into(),
            theta: 0.99,
            rate: None,
            kill: None,
            detect_us: None,
            spans: false,
            obs_out: None,
            trace_out: None,
            flight_dump: None,
            json: false,
            cfg: OpenLoopConfig { ops: fig_fabric::ops_for(scale), ..Default::default() },
        }
    }

    /// Parse `--flag value` pairs (`--spans` and `--json` are bare
    /// flags); unknown flags are errors.
    pub fn parse(scale: Scale, args: &[String]) -> Result<FabricArgs, String> {
        let mut out = FabricArgs::defaults(scale);
        let mut it = args.iter();
        while let Some(flag) = it.next() {
            if flag == "--json" {
                out.json = true;
                continue;
            }
            if flag == "--spans" {
                out.spans = true;
                continue;
            }
            let val = it.next().ok_or_else(|| format!("{flag} needs a value"))?;
            match flag.as_str() {
                "--nodes" => {
                    let xs = val
                        .split(',')
                        .map(|s| {
                            s.trim()
                                .parse::<u8>()
                                .map_err(|_| format!("bad node count {s:?}"))
                                .and_then(|n| {
                                    if (1..=16).contains(&n) {
                                        Ok(n)
                                    } else {
                                        Err(format!("--nodes must be in 1..=16, got {s:?}"))
                                    }
                                })
                        })
                        .collect::<Result<Vec<_>, _>>()?;
                    if xs.is_empty() {
                        return Err("--nodes needs at least one value".into());
                    }
                    out.nodes = xs;
                }
                "--migrate" => {
                    out.modes = match val.as_str() {
                        "on" => vec![true],
                        "off" => vec![false],
                        "both" => vec![false, true],
                        _ => {
                            return Err(format!(
                                "bad --migrate {val:?} (have: on, off, both)"
                            ))
                        }
                    };
                }
                "--threshold" => {
                    let t: u32 = val.parse().map_err(|_| format!("bad threshold {val:?}"))?;
                    if t == 0 {
                        return Err("--threshold must be >= 1".into());
                    }
                    out.threshold = t;
                }
                "--slices" => {
                    let s: usize =
                        val.parse().map_err(|_| format!("bad slice count {val:?}"))?;
                    if s == 0 {
                        return Err("--slices must be >= 1".into());
                    }
                    out.slices = s;
                }
                "--rate" => {
                    out.rate = Some(parse_rate_scalar(val)?);
                }
                "--ops" => {
                    out.cfg.ops = val.parse().map_err(|_| format!("bad op count {val:?}"))?;
                }
                "--scenario" => {
                    out.scenario = check_scenario(val)?;
                }
                "--theta" => {
                    let t: f64 = val.parse().map_err(|_| format!("bad theta {val:?}"))?;
                    if !(t >= 0.0 && t.is_finite()) {
                        return Err(format!("theta must be >= 0, got {val:?}"));
                    }
                    out.theta = t;
                }
                "--seed" => {
                    out.cfg.seed = parse_seed(val)?;
                }
                "--kill" => {
                    let (node, at) = val
                        .split_once('@')
                        .ok_or_else(|| format!("bad --kill {val:?} (want N@US, e.g. 1@200)"))?;
                    let node: u8 = node
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad --kill node {node:?}"))?;
                    let us: u64 = at
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad --kill time {at:?} (microseconds)"))?;
                    if us == 0 {
                        return Err("--kill time must be >= 1 microsecond".into());
                    }
                    out.kill = Some(KillSpec { node, at: Duration::from_us(us) });
                }
                "--detect-us" => {
                    let us: u64 =
                        val.parse().map_err(|_| format!("bad --detect-us {val:?}"))?;
                    if us == 0 {
                        return Err("--detect-us must be >= 1".into());
                    }
                    out.detect_us = Some(us);
                }
                "--obs-out" => {
                    if val.is_empty() {
                        return Err("--obs-out needs a file path".into());
                    }
                    out.obs_out = Some(val.clone());
                }
                "--trace-out" => {
                    if val.is_empty() {
                        return Err("--trace-out needs a file path".into());
                    }
                    out.trace_out = Some(val.clone());
                }
                "--flight-dump" => {
                    if val.is_empty() {
                        return Err("--flight-dump needs a file path".into());
                    }
                    out.flight_dump = Some(val.clone());
                }
                other => return Err(format!("unknown fabric flag {other:?}")),
            }
        }
        if out.cfg.ops == 0 {
            return Err("--ops must be >= 1".into());
        }
        if let Some(k) = out.kill {
            let max = out.nodes.iter().copied().max().unwrap_or(0);
            if max < 2 {
                return Err("--kill needs a sweep point with >= 2 nodes to fail over to".into());
            }
            if k.node >= max {
                return Err(format!(
                    "--kill node {} is outside every swept fabric (max nodes {max})",
                    k.node
                ));
            }
        }
        Ok(out)
    }

    /// The per-node offered rate of the sweep.
    pub fn rate(&self) -> f64 {
        self.rate.unwrap_or_else(|| fig_fabric::saturating_rate(&self.cfg))
    }

    /// Any observability surface requested?
    pub fn observed(&self) -> bool {
        self.spans
            || self.obs_out.is_some()
            || self.trace_out.is_some()
            || self.flight_dump.is_some()
    }
}

/// Parsed `eci bench selfperf` flags: the simulator's own host-side
/// performance trajectory (`harness::selfperf`). Always runs the full
/// pinned workload sizes — `ECI_SCALE` deliberately has no effect, so
/// every measurement is comparable with the committed baseline.
///
/// ```text
/// eci bench selfperf [--check BENCH_6.json] [--record BENCH_6.json]
///                    [--tolerance 0.25] [--json]
/// ```
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SelfperfArgs {
    /// Compare against this baseline file; exit non-zero on a
    /// regression beyond tolerance (calibrated baselines only).
    pub check: Option<String>,
    /// Write the measurement as a calibrated baseline to this path.
    pub record: Option<String>,
    /// Relative tolerance override for `--check`.
    pub tolerance: Option<f64>,
    /// `--json`: emit the measurement as JSON alongside the markdown.
    pub json: bool,
}

impl SelfperfArgs {
    /// Parse `--flag value` pairs (`--json` is a bare flag); unknown
    /// flags are errors.
    pub fn parse(args: &[String]) -> Result<SelfperfArgs, String> {
        let mut out = SelfperfArgs::default();
        let mut it = args.iter();
        while let Some(flag) = it.next() {
            if flag == "--json" {
                out.json = true;
                continue;
            }
            let val = it.next().ok_or_else(|| format!("{flag} needs a value"))?;
            match flag.as_str() {
                "--check" => {
                    if val.is_empty() {
                        return Err("--check needs a baseline path".into());
                    }
                    out.check = Some(val.clone());
                }
                "--record" => {
                    if val.is_empty() {
                        return Err("--record needs a baseline path".into());
                    }
                    out.record = Some(val.clone());
                }
                "--tolerance" => {
                    let t: f64 = val.parse().map_err(|_| format!("bad tolerance {val:?}"))?;
                    if !(t > 0.0 && t < 1.0) {
                        return Err(format!("--tolerance must be in (0, 1), got {val:?}"));
                    }
                    out.tolerance = Some(t);
                }
                other => return Err(format!("unknown selfperf flag {other:?}")),
            }
        }
        Ok(out)
    }
}

/// Parsed `eci bench reconfig` flags. The bench owns only `--scenario`,
/// `--theta` and `--json`; every other flag resolves through
/// [`SystemSpec::FIELDS`], so the spec's field metadata — not this
/// file — is the single home of each spelling.
#[derive(Clone, Debug)]
pub struct ReconfigArgs {
    pub spec: SystemSpec,
    pub scenario: String,
    pub theta: f64,
    /// `--json`: emit the table as JSON alongside the markdown.
    pub json: bool,
}

impl ReconfigArgs {
    pub fn defaults(scale: Scale) -> ReconfigArgs {
        let mut spec = SystemSpec::dcs_cached(2);
        spec.rate_per_s = 6e6;
        spec.ops = fig_reconfig::ops_for(scale);
        // clean reliable framing, so a scripted rel-mode swap is a real
        // swap rather than a recorded no-op
        spec.machine.rel = Some(RelConfig::from_ber(0.0, 0x5EED));
        ReconfigArgs { spec, scenario: "scan".into(), theta: 0.99, json: false }
    }

    /// Parse flags; unknown flags are errors (never silently ignored).
    /// An empty `--reconfig` script falls back to
    /// [`fig_reconfig::default_script`]; the final spec (script
    /// included) is shape-validated before anything runs.
    pub fn parse(scale: Scale, args: &[String]) -> Result<ReconfigArgs, String> {
        let mut out = ReconfigArgs::defaults(scale);
        let mut it = args.iter();
        while let Some(flag) = it.next() {
            match flag.as_str() {
                "--json" => out.json = true,
                "--scenario" => {
                    let val = it.next().ok_or_else(|| format!("{flag} needs a value"))?;
                    out.scenario = check_scenario(val)?;
                }
                "--theta" => {
                    let val = it.next().ok_or_else(|| format!("{flag} needs a value"))?;
                    let t: f64 = val.parse().map_err(|_| format!("bad theta {val:?}"))?;
                    if !(t >= 0.0 && t.is_finite()) {
                        return Err(format!("theta must be >= 0, got {val:?}"));
                    }
                    out.theta = t;
                }
                other => {
                    let Some(takes_value) = SystemSpec::flag_takes_value(other) else {
                        return Err(format!(
                            "unknown reconfig flag {other:?} (spec flags: {})",
                            SystemSpec::FIELDS
                                .iter()
                                .map(|f| f.flag)
                                .collect::<Vec<_>>()
                                .join(", ")
                        ));
                    };
                    let val = if takes_value {
                        it.next().ok_or_else(|| format!("{flag} needs a value"))?.as_str()
                    } else {
                        ""
                    };
                    out.spec
                        .apply_flag(other, val)
                        .expect("flag_takes_value said the spec owns this flag")?;
                }
            }
        }
        if out.spec.reconfig.is_empty() {
            out.spec.reconfig = fig_reconfig::default_script(out.spec.ops, out.spec.rate_per_s);
        }
        out.spec.validate()?;
        Ok(out)
    }
}

/// `--ber` accepts a comma-separated grid of bit-error rates, each in
/// [0, 0.1) (shared by `faults` and `retx`, so the two benches can
/// never diverge on what a legal BER is).
fn parse_ber_list(val: &str) -> Result<Vec<f64>, String> {
    let bers = val
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<f64>()
                .map_err(|_| format!("bad ber {s:?}"))
                .and_then(|b| {
                    if (0.0..0.1).contains(&b) {
                        Ok(b)
                    } else {
                        Err(format!("ber must be in [0, 0.1), got {s:?}"))
                    }
                })
        })
        .collect::<Result<Vec<_>, _>>()?;
    if bers.is_empty() {
        return Err("--ber needs at least one value".into());
    }
    Ok(bers)
}

/// `--burst`: a mean error-burst length in frames, >= 1 (shared by
/// `faults` and `retx`).
fn parse_burst(val: &str) -> Result<f64, String> {
    let b: f64 = val.parse().map_err(|_| format!("bad burst length {val:?}"))?;
    if b >= 1.0 && b.is_finite() {
        Ok(b)
    } else {
        Err(format!("--burst must be >= 1, got {val:?}"))
    }
}

/// A single positive, finite offered rate (ops/s).
fn parse_rate_scalar(val: &str) -> Result<f64, String> {
    let r: f64 = val.parse().map_err(|_| format!("bad rate {val:?}"))?;
    if r > 0.0 && r.is_finite() {
        Ok(r)
    } else {
        Err(format!("rate must be positive, got {val:?}"))
    }
}

/// A scenario preset name (shared by `workload`, `faults` and `retx`).
fn check_scenario(val: &str) -> Result<String, String> {
    if Scenario::preset_names().contains(&val) {
        Ok(val.to_string())
    } else {
        Err(format!(
            "unknown scenario {val:?} (have: {})",
            Scenario::preset_names().join(", ")
        ))
    }
}

/// `--seed` accepts decimal or 0x-prefixed hex.
fn parse_seed(val: &str) -> Result<u64, String> {
    let parsed = match val.strip_prefix("0x").or_else(|| val.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => val.parse(),
    };
    parsed.map_err(|_| format!("bad seed {val:?}"))
}

fn parse_prob(val: &str, flag: &str) -> Result<f64, String> {
    let p: f64 = val.parse().map_err(|_| format!("bad probability {val:?}"))?;
    if (0.0..1.0).contains(&p) {
        Ok(p)
    } else {
        Err(format!("{flag} must be in [0, 1), got {val:?}"))
    }
}

fn parse_usize_list(val: &str) -> Result<Vec<usize>, String> {
    let xs = val
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| format!("bad count {s:?}"))
                .and_then(|n| if n == 0 { Err("count must be >= 1".into()) } else { Ok(n) })
        })
        .collect::<Result<Vec<_>, _>>()?;
    if xs.is_empty() {
        return Err("need at least one value".into());
    }
    Ok(xs)
}

/// Which benches consume command-line flags. Everything else must see
/// an empty flag list: stray flags used to be ignored silently (e.g.
/// `eci bench table3 --mix 60:20:20`, or `eci bench all --batch 4`,
/// quietly running the defaults), which green-washes misconfigured CI
/// smoke steps exactly like an unknown bench id would.
fn bench_rejects_flags(which: &str, rest: &[String]) -> Result<(), String> {
    if matches!(which, "dcs" | "workload" | "faults" | "retx" | "fabric" | "reconfig" | "selfperf")
        || rest.is_empty()
    {
        return Ok(());
    }
    Err(format!(
        "bench {which:?} takes no flags, got {:?} (flags belong to `dcs`, `workload`, `faults`, `retx`, `fabric`, `reconfig` or `selfperf`)",
        rest.join(" ")
    ))
}

fn run_bench(which: &str, scale: Scale, rest: &[String]) {
    const KNOWN: [&str; 13] = [
        "table3", "fig5", "fig6", "fig7", "fig8", "dcs", "workload", "faults", "retx", "fabric",
        "reconfig", "selfperf", "all",
    ];
    if !KNOWN.contains(&which) {
        // a typo must fail loudly, not green-wash a CI smoke step
        eprintln!("eci bench: unknown bench {which:?} (have: {})", KNOWN.join(", "));
        std::process::exit(2);
    }
    if let Err(e) = bench_rejects_flags(which, rest) {
        eprintln!("eci bench: {e}");
        std::process::exit(2);
    }
    let needs_rt = matches!(which, "fig5" | "fig6" | "fig7" | "all");
    let mut rt = if needs_rt {
        Some(Runtime::load_default().expect("artifacts missing — run `make artifacts`"))
    } else {
        None
    };
    if matches!(which, "table3" | "all") {
        println!("{}", table3::render(&table3::run(scale)).to_markdown());
        println!("{}", table3::render_sliced(&table3::run_sliced(scale)).to_markdown());
    }
    if matches!(which, "fig5" | "all") {
        let f = fig5::run(rt.as_mut().unwrap(), scale).expect("fig5");
        println!("{}", fig5::render(&f).to_markdown());
    }
    if matches!(which, "fig6" | "all") {
        let f = fig6::run(rt.as_mut().unwrap(), scale).expect("fig6");
        println!("{}", fig6::render(&f).to_markdown());
    }
    if matches!(which, "fig7" | "all") {
        let f = fig7::run(rt.as_mut().unwrap(), scale).expect("fig7");
        println!("{}", fig7::render(&f).to_markdown());
    }
    if matches!(which, "fig8" | "all") {
        println!("{}", fig8::render(&fig8::run(scale)).to_markdown());
    }
    if matches!(which, "dcs" | "all") {
        let rest = if which == "dcs" { rest } else { &[] };
        let a = match DcsArgs::parse(scale, rest) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("eci bench dcs: {e}");
                std::process::exit(2);
            }
        };
        let f = fig_throughput::run_with_variants(a.cfg, &a.slices, &a.cached_slices, a.batch);
        let t = fig_throughput::render(&f);
        println!("{}", t.to_markdown());
        if a.json {
            println!("{}", t.to_json().pretty());
        }
    }
    if matches!(which, "workload" | "all") {
        let rest = if which == "workload" { rest } else { &[] };
        let a = match WorkloadArgs::parse(scale, rest) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("eci bench workload: {e}");
                std::process::exit(2);
            }
        };
        let scenario = match a.scenario(scale) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("eci bench workload: {e}");
                std::process::exit(2);
            }
        };
        if a.spans || a.obs_out.is_some() || a.trace_out.is_some() {
            // observed mode: one point per slice count at the first
            // rate of the grid, with span tracing / telemetry attached
            run_workload_observed(&a, &scenario);
        } else {
            let f = fig_loadcurve::run_custom_with(
                a.cfg,
                &scenario,
                &a.slices,
                &a.cached_slices,
                &a.rates(),
            );
            for t in [
                fig_loadcurve::render(&f),
                fig_loadcurve::render_classes(&f),
                fig_loadcurve::render_knees(&f),
            ] {
                println!("{}", t.to_markdown());
                if a.json {
                    println!("{}", t.to_json().pretty());
                }
            }
        }
    }
    if matches!(which, "faults" | "all") {
        let rest = if which == "faults" { rest } else { &[] };
        let a = match FaultsArgs::parse(scale, rest) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("eci bench faults: {e}");
                std::process::exit(2);
            }
        };
        let base = fig_loadcurve::footprint_for(scale);
        let scenario = Scenario::preset(&a.scenario, base, 0.99).expect("validated at parse");
        let f = fig_goodput::run_custom_with(
            a.cfg,
            &scenario,
            &a.slices,
            &a.cached_slices,
            &a.bers,
            a.knobs,
            a.rate(),
        );
        let t = fig_goodput::render(&f);
        println!("{}", t.to_markdown());
        if a.json {
            println!("{}", t.to_json().pretty());
        }
    }
    if matches!(which, "retx" | "all") {
        let rest = if which == "retx" { rest } else { &[] };
        let a = match RetxArgs::parse(scale, rest) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("eci bench retx: {e}");
                std::process::exit(2);
            }
        };
        let base = fig_loadcurve::footprint_for(scale);
        let scenario = Scenario::preset(&a.scenario, base, 0.99).expect("validated at parse");
        let f = fig_retx::run_custom_with(a.cfg, &scenario, &a.slices, &a.bers, a.knobs, a.rate());
        let t = fig_retx::render(&f);
        println!("{}", t.to_markdown());
        if a.json {
            println!("{}", t.to_json().pretty());
        }
    }
    if matches!(which, "fabric" | "all") {
        let rest = if which == "fabric" { rest } else { &[] };
        let a = match FabricArgs::parse(scale, rest) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("eci bench fabric: {e}");
                std::process::exit(2);
            }
        };
        let scenario = Scenario::preset(&a.scenario, fig_fabric::footprint_for(scale), a.theta)
            .expect("validated at parse");
        let ol = OpenLoopConfig { rate_per_s: a.rate(), ..a.cfg };
        let mut base =
            FabricConfig { threshold: a.threshold, slices: a.slices, ol, kill: a.kill, ..Default::default() };
        if let Some(us) = a.detect_us {
            base.detect = Duration::from_us(us);
        }
        if a.observed() {
            // observed mode: one point per node count at the first
            // migrate mode, with spans / telemetry / flight attached
            run_fabric_observed(&a, &scenario, base);
        } else {
            let f = fig_fabric::run_custom(base, &scenario, &a.nodes, &a.modes);
            let t = fig_fabric::render(&f);
            println!("{}", t.to_markdown());
            if let Some(ft) = fig_fabric::render_failover(&f) {
                println!("{}", ft.to_markdown());
                if a.json {
                    println!("{}", ft.to_json().pretty());
                }
            }
            if a.json {
                println!("{}", t.to_json().pretty());
            }
        }
    }
    if matches!(which, "reconfig" | "all") {
        let rest = if which == "reconfig" { rest } else { &[] };
        let a = match ReconfigArgs::parse(scale, rest) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("eci bench reconfig: {e}");
                std::process::exit(2);
            }
        };
        let scenario = Scenario::preset(&a.scenario, fig_loadcurve::footprint_for(scale), a.theta)
            .expect("validated at parse");
        let f = fig_reconfig::run_custom(
            a.spec.openloop_config(),
            &scenario,
            a.spec.slices,
            a.spec.reconfig.clone(),
        );
        let t = fig_reconfig::render(&f);
        println!("{}", t.to_markdown());
        if a.json {
            println!("{}", t.to_json().pretty());
        }
    }
    // deliberately NOT part of `all`: selfperf measures the host, not
    // the modeled system, and its wall-clock numbers would add noise to
    // a figure run
    if which == "selfperf" {
        let a = match SelfperfArgs::parse(rest) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("eci bench selfperf: {e}");
                std::process::exit(2);
            }
        };
        let points = selfperf::run();
        println!("{}", selfperf::render(&points).to_markdown());
        if a.json {
            println!("{}", selfperf::to_json(&points, false).pretty());
        }
        if let Some(path) = &a.record {
            let body = selfperf::to_json(&points, true).pretty() + "\n";
            if let Err(e) = std::fs::write(path, body) {
                eprintln!("eci bench selfperf: cannot write {path:?}: {e}");
                std::process::exit(2);
            }
            println!("selfperf: recorded calibrated baseline -> {path}");
        }
        if let Some(path) = &a.check {
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("eci bench selfperf: cannot read {path:?}: {e}");
                    std::process::exit(2);
                }
            };
            let base = match crate::obs::Json::parse(&text) {
                Ok(j) => j,
                Err(e) => {
                    eprintln!("eci bench selfperf: bad baseline {path:?}: {e}");
                    std::process::exit(2);
                }
            };
            let r = selfperf::check(&points, &base, a.tolerance);
            for l in &r.lines {
                println!("selfperf: {l}");
            }
            if !r.pass {
                eprintln!("eci bench selfperf: performance regression beyond tolerance");
                std::process::exit(1);
            }
        }
    }
}

/// `eci bench workload --spans [--obs-out <path>]`: one observed
/// open-loop point per slice count at the first rate of the grid. The
/// waterfall table decomposes the end-to-end latency into the six span
/// stages; its `sum(stages)` row matches the `end_to_end` mean by
/// construction (stages telescope). Telemetry JSONL (when requested)
/// is written from the first slice count's run.
fn run_workload_observed(a: &WorkloadArgs, scenario: &Scenario) {
    use crate::harness::waterfall;
    use crate::obs::ObsConfig;
    let rate = a.rates()[0];
    let ocfg = ObsConfig {
        spans: a.spans || a.trace_out.is_some(),
        span_sample_every: 8,
        record_spans: a.trace_out.is_some(),
        tick: a.obs_out.as_ref().map(|_| waterfall::DEFAULT_TICK),
        ..ObsConfig::default()
    };
    let mut wrote_obs = false;
    for &n in &a.slices {
        let cfg = OpenLoopConfig { rate_per_s: rate, ..a.cfg };
        let (r, obs) = waterfall::run_observed(cfg, scenario, n, &ocfg);
        println!(
            "workload observed: {} slice(s), rate {:.3e}/s, {} completed, e2e p50 {:.0} ns p99 {:.0} ns",
            n,
            rate,
            r.completed,
            r.p50_ns(),
            r.p99_ns()
        );
        if let Some(w) = &obs.waterfall {
            let t = waterfall::render(n, w);
            println!("{}", t.to_markdown());
            if a.json {
                println!("{}", w.to_json().pretty());
            }
        }
        if !wrote_obs {
            if let Some(path) = &a.obs_out {
                if let Err(e) = obs.write_jsonl(path) {
                    eprintln!("eci bench workload: cannot write {path:?}: {e}");
                    std::process::exit(2);
                }
                println!("workload observed: telemetry ({} records) -> {path}", obs.jsonl.len());
            }
            if let Some(path) = &a.trace_out {
                // single-cell host: span keys carry no node bits
                if let Err(e) = obs.write_trace(path, 0) {
                    eprintln!("eci bench workload: cannot write {path:?}: {e}");
                    std::process::exit(2);
                }
                println!(
                    "workload observed: trace ({} spans) -> {path}",
                    obs.span_records.len()
                );
            }
            wrote_obs = true;
        }
    }
}

/// `eci bench fabric --spans [--obs-out <p>] [--trace-out <p>]
/// [--flight-dump <p>]`: one observed fabric point per node count at
/// the first migrate mode. Multi-node waterfalls carry two telescoping
/// classes (local fills and remote fills); the trace export lays spans
/// and flight events out per node; the flight recorder dumps on
/// `declare_dead`, on a deadlock panic, and at end of run. Files are
/// written from the first node count's run.
fn run_fabric_observed(a: &FabricArgs, scenario: &Scenario, base: FabricConfig) {
    use crate::fabric::{Fabric, SPAN_NODE_SHIFT};
    use crate::harness::waterfall;
    use crate::obs::{flight::DEFAULT_FLIGHT_CAP, ObsConfig};
    let migrate = a.modes[0];
    let ocfg = ObsConfig {
        spans: a.spans || a.trace_out.is_some(),
        span_sample_every: 8,
        record_spans: a.trace_out.is_some(),
        tick: a.obs_out.as_ref().map(|_| waterfall::DEFAULT_TICK),
        flight: a.flight_dump.as_ref().map(|_| DEFAULT_FLIGHT_CAP),
        flight_path: a.flight_dump.clone(),
        ..ObsConfig::default()
    };
    let mut wrote = false;
    for &n in &a.nodes {
        let mut cfg = base;
        cfg.nodes = n;
        cfg.migrate = migrate && n > 1;
        // a kill point needs survivors; smaller sweep entries run clean
        cfg.kill = base.kill.filter(|k| n >= 2 && k.node < n);
        let (r, obs) = Fabric::new(cfg, scenario).with_obs(&ocfg).run_observed();
        println!(
            "fabric observed: {} node(s), migrate {}, {} completed, {} remote fills, \
             e2e p50 {:.0} ns p99 {:.0} ns",
            n,
            cfg.migrate,
            r.completed,
            r.fills_remote,
            r.p50_ns(),
            r.p99_ns(),
        );
        if let Some(w) = &obs.waterfall {
            let t = waterfall::render_titled(&format!("{n} node(s)"), w);
            println!("{}", t.to_markdown());
            if a.json {
                println!("{}", w.to_json().pretty());
            }
        }
        if !wrote {
            let die = |path: &String, e: std::io::Error| -> ! {
                eprintln!("eci bench fabric: cannot write {path:?}: {e}");
                std::process::exit(2);
            };
            if let Some(path) = &a.obs_out {
                if let Err(e) = obs.write_jsonl(path) {
                    die(path, e);
                }
                println!("fabric observed: telemetry ({} records) -> {path}", obs.jsonl.len());
            }
            if let Some(path) = &a.trace_out {
                if let Err(e) = obs.write_trace(path, SPAN_NODE_SHIFT) {
                    die(path, e);
                }
                println!("fabric observed: trace ({} spans) -> {path}", obs.span_records.len());
            }
            if let Some(path) = &a.flight_dump {
                if let Err(e) = obs.write_flight(path) {
                    die(path, e);
                }
                println!(
                    "fabric observed: flight recorder ({} dumps) -> {path}",
                    obs.flight_dumps.len()
                );
            }
            wrote = true;
        }
    }
}

fn check() {
    use crate::proto::envelope::{check_envelope, check_recommendations};
    use crate::proto::transitions::reference_transitions;
    let table = reference_transitions();
    let v = check_envelope(&table);
    println!("envelope: {} violations", v.len());
    for x in &v {
        println!("  {x}");
    }
    for note in check_recommendations(&table) {
        println!("  note: {note}");
    }
    let full = Subset::full_symmetric();
    for s in [
        Subset::full_symmetric(),
        Subset::asymmetric_accelerator(),
        Subset::cpu_initiator_readonly(),
        Subset::stateless_readonly(),
    ] {
        // the read-only subsets are only valid under the read-only
        // workload guarantee (R5's escape hatch, §3.3); the stateless home
        // additionally never issues fwds itself
        let workload: &[CohOp] = match s.name {
            "stateless-readonly" => &[CohOp::ReadShared, CohOp::VolDowngradeI],
            "cpu-initiator-readonly" => {
                &[CohOp::ReadShared, CohOp::VolDowngradeI, CohOp::FwdDowngradeI]
            }
            _ => &CohOp::ALL,
        };
        let v = validate_with_workload(&s, &full, workload);
        println!(
            "subset {:<24} home-states={} violations={}",
            s.name,
            s.home_state_count(),
            v.len()
        );
        for x in &v {
            println!("  {x}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_track_scale() {
        assert_eq!(DcsArgs::defaults(Scale::Ci).cfg.ops, 4_000);
        assert_eq!(DcsArgs::defaults(Scale::Paper).cfg.ops, 100_000);
        assert_eq!(DcsArgs::defaults(Scale::Default).slices, vec![1, 2, 4, 8]);
    }

    #[test]
    fn parses_full_flag_set() {
        let a = DcsArgs::parse(
            Scale::Default,
            &s(&[
                "--slices", "1,4",
                "--cached-slices", "2,4",
                "--batch", "4",
                "--theta", "0.99",
                "--clients", "16",
                "--ops", "9000",
                "--mix", "50:30:20",
                "--hops", "8",
            ]),
        )
        .unwrap();
        assert_eq!(a.slices, vec![1, 4]);
        assert_eq!(a.cached_slices, vec![2, 4]);
        assert_eq!(a.batch, 4);
        assert_eq!(a.cfg.theta, 0.99);
        assert_eq!(a.cfg.clients, 16);
        assert_eq!(a.cfg.ops, 9_000);
        assert_eq!(
            a.cfg.mix,
            MixConfig { reads: 50, writes: 30, chases: 20, chase_hops: 8 }
        );
    }

    #[test]
    fn dcs_defaults_are_plain_and_unbatched() {
        let a = DcsArgs::defaults(Scale::Ci);
        assert!(a.cached_slices.is_empty());
        assert_eq!(a.batch, 1);
        assert_eq!(a.cfg.theta, 0.0);
    }

    #[test]
    fn flagless_benches_reject_stray_flags() {
        // the old behavior silently dropped these, green-washing typos
        assert!(bench_rejects_flags("table3", &s(&["--mix", "60:20:20"])).is_err());
        assert!(bench_rejects_flags("all", &s(&["--batch", "4"])).is_err());
        assert!(bench_rejects_flags("fig5", &s(&["--wat"])).is_err());
        // the flag-taking benches and flag-free invocations still pass
        assert!(bench_rejects_flags("dcs", &s(&["--mix", "60:20:20"])).is_ok());
        assert!(bench_rejects_flags("workload", &s(&["--cached-slices", "2"])).is_ok());
        assert!(bench_rejects_flags("faults", &s(&["--ber", "1e-3"])).is_ok());
        assert!(bench_rejects_flags("retx", &s(&["--ber", "1e-3"])).is_ok());
        assert!(bench_rejects_flags("fabric", &s(&["--nodes", "2"])).is_ok());
        assert!(bench_rejects_flags("reconfig", &s(&["--reconfig", "reslice:4@200us"])).is_ok());
        assert!(bench_rejects_flags("selfperf", &s(&["--check", "b.json"])).is_ok());
        assert!(bench_rejects_flags("table3", &[]).is_ok());
        assert!(bench_rejects_flags("all", &[]).is_ok());
    }

    #[test]
    fn json_flag_parses_on_every_table_bench() {
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--json"])).unwrap().json);
        assert!(WorkloadArgs::parse(Scale::Ci, &s(&["--json"])).unwrap().json);
        assert!(FaultsArgs::parse(Scale::Ci, &s(&["--json"])).unwrap().json);
        assert!(RetxArgs::parse(Scale::Ci, &s(&["--json"])).unwrap().json);
        assert!(FabricArgs::parse(Scale::Ci, &s(&["--json"])).unwrap().json);
        assert!(!DcsArgs::defaults(Scale::Ci).json, "json is opt-in");
        // bare flag composes with valued flags on either side
        let a = DcsArgs::parse(Scale::Ci, &s(&["--slices", "2", "--json", "--ops", "100"])).unwrap();
        assert!(a.json);
        assert_eq!(a.slices, vec![2]);
        assert_eq!(a.cfg.ops, 100);
    }

    #[test]
    fn reconfig_args_resolve_through_spec_field_metadata() {
        let a = ReconfigArgs::parse(
            Scale::Ci,
            &s(&[
                "--slices", "4",
                "--rate", "4M",
                "--ops", "5000",
                "--seed", "0xBEEF",
                "--home-cached",
                "--scenario", "uniform",
                "--theta", "0.5",
                "--reconfig", "reslice:8@100us,relmode:sr@200us",
                "--reconfig", "cache:64k@300us",
                "--json",
            ]),
        )
        .unwrap();
        assert_eq!(a.spec.slices, 4);
        assert_eq!(a.spec.rate_per_s, 4e6);
        assert_eq!(a.spec.ops, 5_000);
        assert_eq!(a.spec.seed, 0xBEEF);
        assert!(a.spec.home_cached);
        assert_eq!(a.scenario, "uniform");
        assert_eq!(a.theta, 0.5);
        assert_eq!(a.spec.reconfig.len(), 3, "--reconfig is repeatable and list-valued");
        assert!(a.json);
    }

    #[test]
    fn reconfig_defaults_fall_back_to_the_default_script() {
        let a = ReconfigArgs::parse(Scale::Ci, &[]).unwrap();
        assert_eq!(a.spec.ops, 4_000);
        assert!(a.spec.home_cached);
        assert!(a.spec.machine.rel.is_some(), "rel framing on, so relmode swaps are real");
        assert_eq!(a.spec.reconfig.len(), 5, "default script covers every transition family");
        assert_eq!(a.scenario, "scan");
    }

    #[test]
    fn reconfig_rejects_bad_flags_and_bad_scripts_loudly() {
        assert!(ReconfigArgs::parse(Scale::Ci, &s(&["--wat", "3"])).is_err());
        // a flag another bench owns is still unknown here
        assert!(ReconfigArgs::parse(Scale::Ci, &s(&["--mix", "60:20:20"])).is_err());
        assert!(ReconfigArgs::parse(Scale::Ci, &s(&["--reconfig", "reslice:0@10us"])).is_err());
        // shape-validated before anything runs: rejoin with nothing drained
        assert!(ReconfigArgs::parse(Scale::Ci, &s(&["--reconfig", "rejoin@10us"])).is_err());
        // live reconfiguration is single-cell for now
        assert!(ReconfigArgs::parse(Scale::Ci, &s(&["--nodes", "2"])).is_err());
        assert!(ReconfigArgs::parse(Scale::Ci, &s(&["--scenario", "nope"])).is_err());
        assert!(ReconfigArgs::parse(Scale::Ci, &s(&["--reconfig"])).is_err(), "needs a value");
    }

    #[test]
    fn workload_observability_flags() {
        let a = WorkloadArgs::parse(
            Scale::Ci,
            &s(&["--spans", "--obs-out", "run.jsonl", "--slices", "2"]),
        )
        .unwrap();
        assert!(a.spans);
        assert_eq!(a.obs_out.as_deref(), Some("run.jsonl"));
        assert_eq!(a.slices, vec![2]);
        let d = WorkloadArgs::defaults(Scale::Ci);
        assert!(!d.spans && d.obs_out.is_none(), "observed mode is opt-in");
        assert!(WorkloadArgs::parse(Scale::Ci, &s(&["--obs-out"])).is_err(), "missing path");
        assert!(WorkloadArgs::parse(Scale::Ci, &s(&["--obs-out", ""])).is_err(), "empty path");
        let t = WorkloadArgs::parse(Scale::Ci, &s(&["--trace-out", "run.trace.json"])).unwrap();
        assert_eq!(t.trace_out.as_deref(), Some("run.trace.json"));
        assert!(WorkloadArgs::parse(Scale::Ci, &s(&["--trace-out", ""])).is_err(), "empty path");
    }

    #[test]
    fn fabric_observability_flags() {
        let a = FabricArgs::parse(
            Scale::Ci,
            &s(&[
                "--nodes", "2",
                "--spans",
                "--obs-out", "fab.jsonl",
                "--trace-out", "fab.trace.json",
                "--flight-dump", "post.json",
            ]),
        )
        .unwrap();
        assert!(a.spans && a.observed());
        assert_eq!(a.obs_out.as_deref(), Some("fab.jsonl"));
        assert_eq!(a.trace_out.as_deref(), Some("fab.trace.json"));
        assert_eq!(a.flight_dump.as_deref(), Some("post.json"));
        // each surface alone flips observed mode; defaults stay off
        let d = FabricArgs::defaults(Scale::Ci);
        assert!(!d.spans && !d.observed(), "observed mode is opt-in");
        let f = FabricArgs::parse(Scale::Ci, &s(&["--flight-dump", "p.json"])).unwrap();
        assert!(!f.spans && f.observed());
        assert!(FabricArgs::parse(Scale::Ci, &s(&["--trace-out", ""])).is_err(), "empty path");
        assert!(FabricArgs::parse(Scale::Ci, &s(&["--flight-dump"])).is_err(), "missing path");
    }

    #[test]
    fn selfperf_parses_and_rejects() {
        let a = SelfperfArgs::parse(&s(&[
            "--check", "BENCH_6.json",
            "--tolerance", "0.3",
            "--json",
        ]))
        .unwrap();
        assert_eq!(a.check.as_deref(), Some("BENCH_6.json"));
        assert_eq!(a.tolerance, Some(0.3));
        assert!(a.json && a.record.is_none());
        let a = SelfperfArgs::parse(&s(&["--record", "b.json"])).unwrap();
        assert_eq!(a.record.as_deref(), Some("b.json"));
        assert_eq!(SelfperfArgs::parse(&[]).unwrap(), SelfperfArgs::default());
        assert!(SelfperfArgs::parse(&s(&["--tolerance", "0"])).is_err(), "zero tolerance");
        assert!(SelfperfArgs::parse(&s(&["--tolerance", "1.5"])).is_err(), "tolerance >= 1");
        assert!(SelfperfArgs::parse(&s(&["--check"])).is_err(), "missing value");
        assert!(SelfperfArgs::parse(&s(&["--check", ""])).is_err(), "empty path");
        assert!(SelfperfArgs::parse(&s(&["--wat", "1"])).is_err(), "unknown flag");
    }

    #[test]
    fn faults_parses_rel_mode_and_adaptive_rto() {
        let a = FaultsArgs::parse(Scale::Ci, &[]).unwrap();
        assert_eq!(a.knobs.mode, RelMode::GoBackN, "default stays the PR 4 baseline");
        assert!(!a.knobs.adaptive_rto);
        let a = FaultsArgs::parse(Scale::Ci, &s(&["--mode", "sr", "--adaptive-rto"])).unwrap();
        assert_eq!(a.knobs.mode, RelMode::SelectiveRepeat);
        assert!(a.knobs.adaptive_rto);
        assert!(FaultsArgs::parse(Scale::Ci, &s(&["--mode", "nope"])).is_err());
        assert!(FaultsArgs::parse(Scale::Ci, &s(&["--mode"])).is_err(), "missing value");
    }

    #[test]
    fn retx_defaults_and_full_flag_set() {
        let a = RetxArgs::defaults(Scale::Ci);
        assert_eq!(a.cfg.ops, fig_retx::ops_for(Scale::Ci));
        assert_eq!(a.slices, fig_retx::SLICE_SWEEP.to_vec());
        assert_eq!(a.bers, fig_retx::BER_SWEEP.to_vec());
        assert_eq!(a.scenario, "scan");
        assert!(a.rate() > 0.0, "a default rate must exist");
        let a = RetxArgs::parse(
            Scale::Ci,
            &s(&[
                "--ber", "1e-3",
                "--drop", "0.02",
                "--reorder", "0.01",
                "--burst", "8",
                "--seed", "7",
                "--slices", "2,4",
                "--rate", "2e6",
                "--ops", "900",
                "--scenario", "chase",
            ]),
        )
        .unwrap();
        assert_eq!(a.bers, vec![1e-3]);
        assert_eq!(a.knobs.drop, 0.02);
        assert_eq!(a.knobs.reorder, 0.01);
        assert_eq!(a.knobs.burst_len, 8.0);
        assert_eq!(a.knobs.seed, 7);
        assert_eq!(a.cfg.seed, 7, "--seed drives the traffic draws too");
        assert_eq!(a.slices, vec![2, 4]);
        assert_eq!(a.rate(), 2e6);
        assert_eq!(a.cfg.ops, 900);
        assert_eq!(a.scenario, "chase");
    }

    #[test]
    fn retx_rejects_malformed_input() {
        let bad = |xs: &[&str]| RetxArgs::parse(Scale::Ci, &s(xs)).is_err();
        assert!(bad(&["--ber", "0.5"]), "ber out of range");
        assert!(bad(&["--drop", "1.5"]), "drop out of range");
        assert!(bad(&["--burst", "0.5"]), "burst below 1");
        assert!(bad(&["--rate", "-1"]), "negative rate");
        assert!(bad(&["--ops", "0"]), "zero ops");
        assert!(bad(&["--scenario", "nope"]), "unknown scenario");
        assert!(bad(&["--slices", "0"]), "zero slices");
        assert!(bad(&["--wat", "1"]), "unknown flag");
        assert!(bad(&["--ber"]), "missing value");
        // the discipline grid IS the figure: mode knobs are stray here
        assert!(bad(&["--mode", "sr"]), "mode belongs to `faults`");
        assert!(bad(&["--adaptive-rto", "1"]), "adaptive-rto belongs to `faults`");
        assert!(bad(&["--cached-slices", "2"]), "no cached sweep on retx");
    }

    #[test]
    fn seed_flag_reseeds_every_stochastic_bench() {
        let d = DcsArgs::parse(Scale::Ci, &s(&["--seed", "42"])).unwrap();
        assert_eq!(d.cfg.seed, 42);
        assert_eq!(DcsArgs::defaults(Scale::Ci).cfg.seed, 0xDC5, "documented default");
        let w = WorkloadArgs::parse(Scale::Ci, &s(&["--seed", "0xBEEF"])).unwrap();
        assert_eq!(w.cfg.seed, 0xBEEF, "hex seeds accepted");
        assert_eq!(WorkloadArgs::defaults(Scale::Ci).cfg.seed, 0x0C3A, "documented default");
        let f = FaultsArgs::parse(Scale::Ci, &s(&["--seed", "7"])).unwrap();
        assert_eq!(f.knobs.seed, 7, "--seed drives fault injection");
        assert_eq!(f.cfg.seed, 7, "--seed drives the traffic draws too");
        let fb = FabricArgs::parse(Scale::Ci, &s(&["--seed", "0x7AB"])).unwrap();
        assert_eq!(fb.cfg.seed, 0x7AB, "fabric takes the global seed too");
        assert_eq!(FabricArgs::defaults(Scale::Ci).cfg.seed, 0x0C3A, "documented default");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--seed", "nope"])).is_err());
    }

    #[test]
    fn fabric_defaults_and_full_flag_set() {
        let a = FabricArgs::defaults(Scale::Ci);
        assert_eq!(a.cfg.ops, fig_fabric::ops_for(Scale::Ci));
        assert_eq!(a.nodes, fig_fabric::node_sweep(Scale::Ci));
        assert_eq!(a.modes, vec![false, true], "both migration settings by default");
        assert_eq!(a.scenario, "hot-kvs");
        assert_eq!(a.threshold, FabricConfig::default().threshold);
        assert_eq!(a.slices, FabricConfig::default().slices);
        assert!(a.rate() > 0.0, "a default per-node rate must exist");
        let a = FabricArgs::parse(
            Scale::Ci,
            &s(&[
                "--nodes", "1,2,4",
                "--migrate", "on",
                "--threshold", "4",
                "--slices", "1",
                "--rate", "2e6",
                "--ops", "900",
                "--scenario", "uniform",
                "--theta", "1.1",
                "--seed", "7",
            ]),
        )
        .unwrap();
        assert_eq!(a.nodes, vec![1, 2, 4]);
        assert_eq!(a.modes, vec![true]);
        assert_eq!(a.threshold, 4);
        assert_eq!(a.slices, 1);
        assert_eq!(a.rate(), 2e6);
        assert_eq!(a.cfg.ops, 900);
        assert_eq!(a.scenario, "uniform");
        assert_eq!(a.theta, 1.1);
        assert_eq!(a.cfg.seed, 7);
        let a = FabricArgs::parse(Scale::Ci, &s(&["--migrate", "off"])).unwrap();
        assert_eq!(a.modes, vec![false]);
        let a = FabricArgs::parse(Scale::Ci, &s(&["--migrate", "both"])).unwrap();
        assert_eq!(a.modes, vec![false, true]);
        assert!(a.kill.is_none(), "no kill unless asked for");
        let a = FabricArgs::parse(
            Scale::Ci,
            &s(&["--nodes", "3", "--kill", "1@200", "--detect-us", "25"]),
        )
        .unwrap();
        let k = a.kill.expect("--kill parsed");
        assert_eq!(k.node, 1);
        assert_eq!(k.at, Duration::from_us(200));
        assert_eq!(a.detect_us, Some(25));
    }

    #[test]
    fn fabric_rejects_malformed_input() {
        let bad = |xs: &[&str]| FabricArgs::parse(Scale::Ci, &s(xs)).is_err();
        assert!(bad(&["--nodes", "0"]), "zero nodes");
        assert!(bad(&["--nodes", "17"]), "node count beyond the fabric limit");
        assert!(bad(&["--nodes", "two"]), "non-numeric nodes");
        assert!(bad(&["--nodes", ""]), "empty node list");
        assert!(bad(&["--migrate", "sometimes"]), "bad migrate mode");
        assert!(bad(&["--threshold", "0"]), "zero threshold");
        assert!(bad(&["--slices", "0"]), "zero slices");
        assert!(bad(&["--rate", "-1"]), "negative rate");
        assert!(bad(&["--ops", "0"]), "zero ops");
        assert!(bad(&["--scenario", "nope"]), "unknown scenario");
        assert!(bad(&["--theta", "-0.5"]), "negative theta");
        assert!(bad(&["--wat", "1"]), "unknown flag");
        assert!(bad(&["--nodes"]), "missing value");
        // workload/faults-only knobs are stray here and must fail loudly
        assert!(bad(&["--cached-slices", "2"]), "no cached sweep on fabric");
        assert!(bad(&["--ber", "1e-3"]), "fault knobs belong to `faults`");
        assert!(bad(&["--kill", "1"]), "kill needs N@US");
        assert!(bad(&["--kill", "x@200"]), "non-numeric kill node");
        assert!(bad(&["--kill", "1@x"]), "non-numeric kill time");
        assert!(bad(&["--kill", "1@0"]), "kill at time zero");
        assert!(bad(&["--nodes", "1", "--kill", "0@200"]), "no survivors to fail over to");
        assert!(bad(&["--nodes", "2", "--kill", "2@200"]), "kill node outside every sweep");
        assert!(bad(&["--nodes", "3", "--kill", "1@200", "--detect-us", "0"]), "zero watchdog");
    }

    #[test]
    fn faults_defaults_and_full_flag_set() {
        let a = FaultsArgs::defaults(Scale::Ci);
        assert_eq!(a.cfg.ops, fig_goodput::ops_for(Scale::Ci));
        assert_eq!(a.slices, fig_goodput::SLICE_SWEEP.to_vec());
        assert_eq!(a.bers, fig_goodput::BER_SWEEP.to_vec());
        assert_eq!(a.scenario, "scan");
        assert!(a.rate() > 0.0, "a default rate must exist");
        let a = FaultsArgs::parse(
            Scale::Ci,
            &s(&[
                "--ber", "1e-6,1e-3",
                "--drop", "0.02",
                "--reorder", "0.01",
                "--burst", "8",
                "--seed", "7",
                "--slices", "1,4",
                "--cached-slices", "2",
                "--rate", "2e6",
                "--ops", "900",
                "--scenario", "chase",
            ]),
        )
        .unwrap();
        assert_eq!(a.bers, vec![1e-6, 1e-3]);
        assert_eq!(a.knobs.drop, 0.02);
        assert_eq!(a.knobs.reorder, 0.01);
        assert_eq!(a.knobs.burst_len, 8.0);
        assert_eq!(a.knobs.seed, 7);
        assert_eq!(a.slices, vec![1, 4]);
        assert_eq!(a.cached_slices, vec![2]);
        assert_eq!(a.rate(), 2e6);
        assert_eq!(a.cfg.ops, 900);
        assert_eq!(a.scenario, "chase");
    }

    #[test]
    fn faults_rejects_malformed_input() {
        let bad = |xs: &[&str]| FaultsArgs::parse(Scale::Ci, &s(xs)).is_err();
        assert!(bad(&["--ber", "0.5"]), "ber out of range");
        assert!(bad(&["--ber", "x"]), "non-numeric ber");
        assert!(bad(&["--drop", "1.5"]), "drop out of range");
        assert!(bad(&["--reorder", "-0.1"]), "negative reorder");
        assert!(bad(&["--burst", "0.5"]), "burst below 1");
        assert!(bad(&["--rate", "-1"]), "negative rate");
        assert!(bad(&["--ops", "0"]), "zero ops");
        assert!(bad(&["--scenario", "nope"]), "unknown scenario");
        assert!(bad(&["--slices", "0"]), "zero slices");
        assert!(bad(&["--cached-slices", "2000"]), "cached slices beyond the budget");
        assert!(bad(&["--wat", "1"]), "unknown flag");
        assert!(bad(&["--ber"]), "missing value");
    }

    #[test]
    fn empty_args_give_defaults() {
        let a = DcsArgs::parse(Scale::Ci, &[]).unwrap();
        assert_eq!(a, DcsArgs::defaults(Scale::Ci));
    }

    #[test]
    fn workload_defaults_track_scale() {
        let a = WorkloadArgs::defaults(Scale::Ci);
        assert_eq!(a.cfg.ops, fig_loadcurve::ops_for(Scale::Ci));
        assert_eq!(a.slices, vec![1, 2, 4, 8]);
        assert_eq!(a.scenario, "tenants");
        assert!(!a.cfg.cached);
        assert!(!a.rates().is_empty(), "a default rate grid must exist");
        assert!(a.scenario(Scale::Ci).is_ok());
    }

    #[test]
    fn workload_parses_full_flag_set() {
        let a = WorkloadArgs::parse(
            Scale::Default,
            &s(&[
                "--scenario", "hot-kvs",
                "--slices", "1,4",
                "--cached-slices", "4",
                "--batch", "8",
                "--rate", "2e6,8e6",
                "--theta", "1.2",
                "--ops", "5000",
                "--arrivals", "fixed",
                "--cached",
            ]),
        )
        .unwrap();
        assert_eq!(a.scenario, "hot-kvs");
        assert_eq!(a.slices, vec![1, 4]);
        assert_eq!(a.cached_slices, vec![4]);
        assert_eq!(a.cfg.machine.ingress_batch, 8);
        assert_eq!(a.rates(), vec![2e6, 8e6]);
        assert_eq!(a.theta, 1.2);
        assert_eq!(a.cfg.ops, 5_000);
        assert_eq!(a.cfg.arrivals, crate::workload::ArrivalKind::Deterministic);
        assert!(a.cfg.cached);
        assert!(!a.cfg.home_cached, "--cached-slices selects curves, not the base cfg");
    }

    #[test]
    fn workload_classes_compose_a_custom_scenario() {
        let a = WorkloadArgs::parse(Scale::Ci, &s(&["--classes", "hot-kvs:2,scan"])).unwrap();
        let sc = a.scenario(Scale::Ci).unwrap();
        assert_eq!(sc.name, "custom");
        assert_eq!(sc.classes.len(), 2);
        assert_eq!(sc.classes[0].rate_weight, 2);
        assert_eq!(sc.classes[1].rate_weight, 1);
    }

    #[test]
    fn workload_rejects_malformed_input() {
        let bad = |xs: &[&str]| WorkloadArgs::parse(Scale::Ci, &s(xs)).is_err();
        assert!(bad(&["--scenario", "nope"]), "unknown scenario");
        assert!(bad(&["--slices", "0"]), "zero slices");
        assert!(bad(&["--rate", "-1"]), "negative rate");
        assert!(bad(&["--rate", "x"]), "non-numeric rate");
        assert!(bad(&["--theta", "-0.5"]), "negative theta");
        assert!(bad(&["--classes", "scan:0"]), "zero weight");
        assert!(bad(&["--ops", "0"]), "zero ops");
        assert!(bad(&["--arrivals", "sometimes"]), "bad arrival kind");
        assert!(bad(&["--wat", "1"]), "unknown flag");
        assert!(bad(&["--rate"]), "missing value");
        // an unknown class name parses but fails at scenario build time
        let a = WorkloadArgs::parse(Scale::Ci, &s(&["--classes", "wat:1"])).unwrap();
        assert!(a.scenario(Scale::Ci).is_err());
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--slices"])).is_err(), "missing value");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--slices", "0"])).is_err(), "zero slices");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--slices", "two"])).is_err(), "non-numeric");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--mix", "1:2"])).is_err(), "short mix");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--mix", "0:0:0"])).is_err(), "empty mix");
        assert!(
            DcsArgs::parse(Scale::Ci, &s(&["--mix", "4000000000:1000000000:0"])).is_err(),
            "overflowing mix weights"
        );
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--ops", "0"])).is_err(), "zero ops");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--wat", "1"])).is_err(), "unknown flag");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--clients", "0"])).is_err(), "zero clients");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--batch", "0"])).is_err(), "zero batch");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--batch", "x"])).is_err(), "non-numeric batch");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--cached-slices", "0"])).is_err(), "zero cached slices");
        assert!(
            DcsArgs::parse(Scale::Ci, &s(&["--cached-slices", "2000"])).is_err(),
            "cached slices beyond the home-cache budget"
        );
        assert!(
            WorkloadArgs::parse(Scale::Ci, &s(&["--cached-slices", "2000"])).is_err(),
            "wl cached slices beyond the home-cache budget"
        );
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--theta", "-1"])).is_err(), "negative theta");
        assert!(WorkloadArgs::parse(Scale::Ci, &s(&["--batch", "0"])).is_err(), "zero wl batch");
        assert!(
            WorkloadArgs::parse(Scale::Ci, &s(&["--cached-slices", "nope"])).is_err(),
            "non-numeric cached slices"
        );
    }
}
