//! The `eci` command-line launcher (hand-rolled arg parsing — `clap` is
//! not available in the offline registry).
//!
//! ```text
//! eci resources                  print Table 2 + subsetting ablation
//! eci bench <table3|fig5|fig6|fig7|fig8|dcs|all> [dcs flags]
//! eci check                      validate envelope + subsets, print report
//! eci trace-demo                 run a traffic capture through the
//!                                dissector and the online checker
//! ```
//! `ECI_SCALE={ci,default,paper}` controls workload sizes.
//!
//! The `dcs` bench (directory-slice throughput sweep) takes flags so
//! slice counts and the load-generator mix can be swept from the command
//! line:
//!
//! ```text
//! eci bench dcs [--slices 1,2,4,8] [--clients 32] [--ops 20000]
//!               [--mix 60:20:20] [--hops 4]
//! ```

use crate::dcs::loadgen::{LoadGenConfig, MixConfig};
use crate::harness::{fig5, fig6, fig7, fig8, fig_throughput, table2, table3, Scale};
use crate::proto::messages::CohOp;
use crate::proto::subset::{validate_with_workload, Subset};
use crate::runtime::Runtime;

pub fn main_entry() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let scale = Scale::from_env();
    match cmd {
        "resources" => {
            for t in table2::render() {
                println!("{}", t.to_markdown());
            }
        }
        "bench" => {
            let which = args.get(1).map(String::as_str).unwrap_or("all");
            run_bench(which, scale, &args[2.min(args.len())..]);
        }
        "check" => check(),
        "trace-demo" => crate::trace::demo::run_demo(),
        _ => {
            eprintln!(
                "usage: eci <resources|bench [table3|fig5|fig6|fig7|fig8|dcs|all]|check|trace-demo>\n\
                 dcs flags: --slices 1,2,4,8 --clients 32 --ops 20000 --mix 60:20:20 --hops 4\n\
                 env: ECI_SCALE={{ci,default,paper}} (current: {scale:?})"
            );
        }
    }
}

/// Parsed `eci bench dcs` flags: slice sweep + load-generator shape.
#[derive(Clone, Debug, PartialEq)]
pub struct DcsArgs {
    pub slices: Vec<usize>,
    pub cfg: LoadGenConfig,
}

impl DcsArgs {
    pub fn defaults(scale: Scale) -> DcsArgs {
        DcsArgs {
            slices: fig_throughput::SLICE_SWEEP.to_vec(),
            cfg: LoadGenConfig { ops: fig_throughput::ops_for(scale), ..Default::default() },
        }
    }

    /// Parse `--flag value` pairs; unknown flags are errors.
    pub fn parse(scale: Scale, args: &[String]) -> Result<DcsArgs, String> {
        let mut out = DcsArgs::defaults(scale);
        let mut it = args.iter();
        while let Some(flag) = it.next() {
            let val = it
                .next()
                .ok_or_else(|| format!("{flag} needs a value"))?;
            match flag.as_str() {
                "--slices" => {
                    out.slices = val
                        .split(',')
                        .map(|s| {
                            s.trim()
                                .parse::<usize>()
                                .map_err(|_| format!("bad slice count {s:?}"))
                                .and_then(|n| {
                                    if n == 0 {
                                        Err("slice count must be >= 1".into())
                                    } else {
                                        Ok(n)
                                    }
                                })
                        })
                        .collect::<Result<Vec<_>, _>>()?;
                    if out.slices.is_empty() {
                        return Err("--slices needs at least one value".into());
                    }
                }
                "--clients" => {
                    out.cfg.clients =
                        val.parse().map_err(|_| format!("bad client count {val:?}"))?;
                }
                "--ops" => {
                    out.cfg.ops = val.parse().map_err(|_| format!("bad op count {val:?}"))?;
                }
                "--mix" => {
                    // weights are ratios; cap them so the u32 weight sum
                    // can never overflow in MixConfig::total()
                    const MAX_WEIGHT: u32 = 1_000_000;
                    let parts: Vec<u32> = val
                        .split(':')
                        .map(|p| p.trim().parse::<u32>().map_err(|_| format!("bad mix {val:?}")))
                        .collect::<Result<Vec<_>, _>>()?;
                    let &[r, w, c] = parts.as_slice() else {
                        return Err(format!("--mix wants reads:writes:chases, got {val:?}"));
                    };
                    if r == 0 && w == 0 && c == 0 {
                        return Err("--mix must not be all zero".into());
                    }
                    if r.max(w).max(c) > MAX_WEIGHT {
                        return Err(format!("--mix weights must be <= {MAX_WEIGHT}"));
                    }
                    out.cfg.mix = MixConfig { reads: r, writes: w, chases: c, ..out.cfg.mix };
                }
                "--hops" => {
                    out.cfg.mix.chase_hops =
                        val.parse().map_err(|_| format!("bad hop count {val:?}"))?;
                }
                other => return Err(format!("unknown dcs flag {other:?}")),
            }
        }
        if out.cfg.clients == 0 {
            return Err("--clients must be >= 1".into());
        }
        if out.cfg.ops == 0 {
            return Err("--ops must be >= 1".into());
        }
        Ok(out)
    }
}

fn run_bench(which: &str, scale: Scale, rest: &[String]) {
    let needs_rt = matches!(which, "fig5" | "fig6" | "fig7" | "all");
    let mut rt = if needs_rt {
        Some(Runtime::load_default().expect("artifacts missing — run `make artifacts`"))
    } else {
        None
    };
    if matches!(which, "table3" | "all") {
        println!("{}", table3::render(&table3::run(scale)).to_markdown());
    }
    if matches!(which, "fig5" | "all") {
        let f = fig5::run(rt.as_mut().unwrap(), scale).expect("fig5");
        println!("{}", fig5::render(&f).to_markdown());
    }
    if matches!(which, "fig6" | "all") {
        let f = fig6::run(rt.as_mut().unwrap(), scale).expect("fig6");
        println!("{}", fig6::render(&f).to_markdown());
    }
    if matches!(which, "fig7" | "all") {
        let f = fig7::run(rt.as_mut().unwrap(), scale).expect("fig7");
        println!("{}", fig7::render(&f).to_markdown());
    }
    if matches!(which, "fig8" | "all") {
        println!("{}", fig8::render(&fig8::run(scale)).to_markdown());
    }
    if matches!(which, "dcs" | "all") {
        let a = match DcsArgs::parse(scale, rest) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("eci bench dcs: {e}");
                std::process::exit(2);
            }
        };
        let f = fig_throughput::run_with(a.cfg, &a.slices);
        println!("{}", fig_throughput::render(&f).to_markdown());
    }
}

fn check() {
    use crate::proto::envelope::{check_envelope, check_recommendations};
    use crate::proto::transitions::reference_transitions;
    let table = reference_transitions();
    let v = check_envelope(&table);
    println!("envelope: {} violations", v.len());
    for x in &v {
        println!("  {x}");
    }
    for note in check_recommendations(&table) {
        println!("  note: {note}");
    }
    let full = Subset::full_symmetric();
    for s in [
        Subset::full_symmetric(),
        Subset::asymmetric_accelerator(),
        Subset::cpu_initiator_readonly(),
        Subset::stateless_readonly(),
    ] {
        // the read-only subsets are only valid under the read-only
        // workload guarantee (R5's escape hatch, §3.3); the stateless home
        // additionally never issues fwds itself
        let workload: &[CohOp] = match s.name {
            "stateless-readonly" => &[CohOp::ReadShared, CohOp::VolDowngradeI],
            "cpu-initiator-readonly" => {
                &[CohOp::ReadShared, CohOp::VolDowngradeI, CohOp::FwdDowngradeI]
            }
            _ => &CohOp::ALL,
        };
        let v = validate_with_workload(&s, &full, workload);
        println!(
            "subset {:<24} home-states={} violations={}",
            s.name,
            s.home_state_count(),
            v.len()
        );
        for x in &v {
            println!("  {x}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_track_scale() {
        assert_eq!(DcsArgs::defaults(Scale::Ci).cfg.ops, 4_000);
        assert_eq!(DcsArgs::defaults(Scale::Paper).cfg.ops, 100_000);
        assert_eq!(DcsArgs::defaults(Scale::Default).slices, vec![1, 2, 4, 8]);
    }

    #[test]
    fn parses_full_flag_set() {
        let a = DcsArgs::parse(
            Scale::Default,
            &s(&["--slices", "1,4", "--clients", "16", "--ops", "9000", "--mix", "50:30:20", "--hops", "8"]),
        )
        .unwrap();
        assert_eq!(a.slices, vec![1, 4]);
        assert_eq!(a.cfg.clients, 16);
        assert_eq!(a.cfg.ops, 9_000);
        assert_eq!(
            a.cfg.mix,
            MixConfig { reads: 50, writes: 30, chases: 20, chase_hops: 8 }
        );
    }

    #[test]
    fn empty_args_give_defaults() {
        let a = DcsArgs::parse(Scale::Ci, &[]).unwrap();
        assert_eq!(a, DcsArgs::defaults(Scale::Ci));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--slices"])).is_err(), "missing value");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--slices", "0"])).is_err(), "zero slices");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--slices", "two"])).is_err(), "non-numeric");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--mix", "1:2"])).is_err(), "short mix");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--mix", "0:0:0"])).is_err(), "empty mix");
        assert!(
            DcsArgs::parse(Scale::Ci, &s(&["--mix", "4000000000:1000000000:0"])).is_err(),
            "overflowing mix weights"
        );
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--ops", "0"])).is_err(), "zero ops");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--wat", "1"])).is_err(), "unknown flag");
        assert!(DcsArgs::parse(Scale::Ci, &s(&["--clients", "0"])).is_err(), "zero clients");
    }
}
