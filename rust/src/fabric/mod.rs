//! fabric — the N-node scale-out composition of the two-socket unit
//! cell.
//!
//! Every node is a full open-loop cell (its own sliced directory, FPGA
//! DRAM, KVS pool, streaming/caching client behind real link framing —
//! exactly the [`crate::workload::openloop`] machinery), and the nodes
//! are joined by an inter-node fabric: one framed, credit-managed,
//! optionally reliable link pair per ordered node pair, the same
//! [`FramedIngress`] transport the intra-node links use.
//!
//! Three mechanisms make it a coherence fabric rather than N isolated
//! machines (DESIGN.md §"The fabric subsystem"):
//!
//! * **Global interleave** ([`route::Interleave`]) — every line has
//!   exactly one home node (`addr % nodes`, plus a sparse override
//!   table for migrated lines). A request whose line homes elsewhere is
//!   *forwarded*: the local hop's credit is returned, the message
//!   crosses the fabric link, and the response crosses back — the
//!   two-hop remote-fill path whose cost the `fig_fabric` experiment
//!   measures.
//! * **Id translation** ([`route::IdTranslator`]) — each node's client
//!   numbers its transactions independently, so requests from N clients
//!   meeting at one home directory would collide. The forwarding point
//!   swaps the id for a fabric-unique one (bit 31 set) and the
//!   responding home restores the original, because the source client
//!   matches responses by id.
//! * **Home migration** ([`migrate::Migrator`]) — a line whose traffic
//!   is dominated by one remote node moves its home there.  The move is
//!   a quiesce-and-handoff: new transactions for the line park, in-
//!   flight ones drain (live count reaches zero), the old home flushes
//!   any cached copy and drops its directory entry
//!   ([`crate::dcs::Dcs::surrender_local`]), the backing bytes and the
//!   interleave entry move, and the parked requests are re-injected at
//!   the new home — no request ever observes the line mid-move.  An
//!   `UpgradeS2E` arriving mid-move *aborts* the move instead of
//!   parking: its issuer holds the line in `S`, so the line could never
//!   quiesce while the upgrade waits.
//!
//! A fourth mechanism handles **whole-node failure** (DESIGN.md
//! §"Failure semantics"): a scripted kill silences one node's cell and
//! channels mid-run; the survivors detect the silence (barren
//! retransmissions on a reliable fabric link, or the bounded watchdog
//! on a clean one), declare the node dead exactly once, re-interleave
//! its homed lines across themselves, rebuild each re-homed line's
//! directory view from survivor cache truth, close the possession
//! epochs the dead node still held, and replay every in-flight request
//! whose translation entry is still pending — entries retire only when
//! the response *lands* at its source, so "entry pending" is exactly
//! "source still waiting" and each replayed request completes exactly
//! once.
//!
//! Determinism carries over from the unit cell: with one node, the
//! fabric's RNG stream, event sequence, and settled-state digest are
//! bit-identical to a bare [`crate::workload::OpenLoop`] (the
//! `one_node_fabric_equals_openloop` gate in `tests/fabric.rs`).

pub mod migrate;
pub mod route;

pub use migrate::Migrator;
pub use route::{IdTranslator, Interleave};

use std::collections::VecDeque;

use crate::agents::cache::Cache;
use crate::agents::dram::{Dram, MemStore};
use crate::agents::home::HomeEffect;
use crate::agents::remote::{Access, RemoteAgent, RemoteEffect};
use crate::dcs::{Dcs, SliceService};
use crate::memctl::KvsService;
use crate::obs::{FlightKind, Obs, ObsConfig, ObsReport, Registry, Stage};
use crate::proto::messages::{CohOp, LineAddr, Message, MsgKind, ReqId};
use crate::proto::spec::{generate_remote, PendingFwd, RemoteView};
use crate::proto::states::{CacheState, Node};
use crate::proto::transitions::reference_transitions;
use crate::rustc_hash::{FxHashMap as HashMap, FxHashSet as HashSet};
use crate::sim::engine::Engine;
use crate::sim::rng::{stream_seed, Rng};
use crate::sim::stats::{Counters, Histogram};
use crate::sim::time::{Duration, Time};
use crate::transport::{vc_for, Control, Frame, FramedIngress, VcId};
use crate::workload::openloop::OpenLoopConfig;
use crate::workload::sampler::{SampleKind, TrafficSampler};
use crate::workload::scenario::Scenario;

/// Fabric parameters. The per-node cell (offered rate, client style,
/// link, directory pipeline) comes from the embedded
/// [`OpenLoopConfig`]; `rate_per_s` is *per node* while `ops` is the
/// fabric-wide total (split evenly, remainder to the low nodes).
#[derive(Clone, Copy, Debug)]
pub struct FabricConfig {
    pub nodes: u8,
    /// Enable threshold-based home migration.
    pub migrate: bool,
    /// Response-needing requests from one remote node before its lines
    /// migrate toward it.
    pub threshold: u32,
    /// Directory slices per node.
    pub slices: usize,
    /// Scripted whole-node failure: the node goes dark (cell and all
    /// channel endpoints silenced) at the given sim time.
    pub kill: Option<KillSpec>,
    /// Watchdog bound on failure detection: survivors declare a killed
    /// node dead at most this long after it went dark, even when no
    /// reliable-link retransmission traffic points at it first.
    pub detect: Duration,
    /// Fault injection for the migration *abort* path: every begun move
    /// aborts at its first commit check instead of committing, so
    /// parked requests always replay against the old home.
    pub abort_inject: bool,
    pub ol: OpenLoopConfig,
}

/// Scripted kill of one node at a sim time.
#[derive(Clone, Copy, Debug)]
pub struct KillSpec {
    pub node: u8,
    pub at: Duration,
}

impl Default for FabricConfig {
    fn default() -> FabricConfig {
        FabricConfig {
            nodes: 2,
            migrate: false,
            threshold: 8,
            slices: 2,
            kill: None,
            detect: Duration::from_us(40),
            abort_inject: false,
            ol: OpenLoopConfig::default(),
        }
    }
}

/// Per-node results.
#[derive(Clone, Debug)]
pub struct FabricNodeReport {
    pub node: usize,
    pub completed: u64,
    /// Arrival-to-completion latency of this node's operations, ps.
    pub lat: Histogram,
    pub fills_local: u64,
    pub fills_remote: u64,
    pub migrations_in: u64,
    pub migrations_out: u64,
    pub credit_stalls: u64,
    pub counters: Counters,
}

/// Results of one fabric run.
#[derive(Debug)]
pub struct FabricReport {
    pub scenario: String,
    pub nodes: usize,
    pub migrate: bool,
    /// Aggregate configured arrival rate (per-node rate x nodes).
    pub offered_per_s: f64,
    /// Aggregate completions over total simulated time.
    pub delivered_per_s: f64,
    pub completed: u64,
    pub sim_time: Time,
    /// Fabric-wide operation latency: the per-node histograms merged
    /// ([`Histogram::merge`]), ps.
    pub lat: Histogram,
    /// Per-frame inter-node hop latency (launch to landing), ps — empty
    /// on a 1-node fabric.
    pub hop_lat: Histogram,
    /// Fills served by the requester's own home slice vs. across the
    /// fabric (two-hop path).
    pub fills_local: u64,
    pub fills_remote: u64,
    /// Committed home migrations.
    pub migrations: u64,
    /// Lines living away from their natural interleave home at the end.
    pub moved_lines: usize,
    /// Simulator events dispatched (host-side cost; the selfperf
    /// metric).
    pub events: u64,
    /// Whole-node-failure outcome (present iff the run was configured
    /// with a [`KillSpec`]).
    pub kill: Option<KillReport>,
    pub per_node: Vec<FabricNodeReport>,
    pub counters: Counters,
}

/// What the failover machinery did during a killed run.
#[derive(Clone, Debug)]
pub struct KillReport {
    pub node: u8,
    /// When the node went dark (`None` if the run finished first).
    pub killed_at: Option<Time>,
    /// When the survivors declared it dead.
    pub declared_at: Option<Time>,
    /// Lines re-interleaved off the dead node onto survivors.
    pub rehomed_lines: u64,
    /// In-flight requests replayed against their new home.
    pub replayed: u64,
    /// Possession epochs held by the dead node closed on its behalf.
    pub reclaimed_epochs: u64,
    /// Dead-sourced requests dropped (no requester left to answer).
    pub dropped_requests: u64,
    /// Responses to the dead node dropped at generation.
    pub dropped_responses: u64,
    /// The dead node's unfinished arrival quota, subtracted from the
    /// fabric completion target.
    pub abandoned_ops: u64,
    /// Completion timestamp (ps) of every finished op, for the
    /// goodput-dip timeline.
    pub completion_ps: Vec<u64>,
}

impl KillReport {
    /// Kill-to-declaration latency, when both happened.
    pub fn detect_latency(&self) -> Option<Duration> {
        match (self.killed_at, self.declared_at) {
            (Some(k), Some(d)) => Some(d.since(k)),
            _ => None,
        }
    }
}

impl FabricReport {
    pub fn p50_ns(&self) -> f64 {
        self.lat.p50() as f64 / 1000.0
    }
    pub fn p99_ns(&self) -> f64 {
        self.lat.p99() as f64 / 1000.0
    }
    pub fn p999_ns(&self) -> f64 {
        self.lat.p999() as f64 / 1000.0
    }
    pub fn hop_p99_ns(&self) -> f64 {
        self.hop_lat.p99() as f64 / 1000.0
    }
    /// Remote share of all coherence fills.
    pub fn remote_fill_frac(&self) -> f64 {
        let total = self.fills_local + self.fills_remote;
        if total == 0 {
            0.0
        } else {
            self.fills_remote as f64 / total as f64
        }
    }
}

#[derive(Clone, Copy, Debug)]
enum OpKind {
    Read,
    Write,
    Chase { left: u64 },
}

#[derive(Clone, Copy, Debug)]
struct OpCtx {
    kind: OpKind,
    addr: LineAddr,
    started: Time,
    active: bool,
}

/// Where an admitted directory message came from — decides where its
/// held request-direction credit flows back to when the slice consumes
/// it.
#[derive(Clone, Copy, Debug)]
enum Source {
    /// The home node's own client link.
    Local,
    /// A fabric channel's request direction.
    Chan(u16),
    /// Re-injected after parking (its original credit was returned at
    /// park time).
    Parked,
}

/// What the migration gate decided about an arriving request.
enum Gate {
    Admit,
    Park,
}

/// One node: the full open-loop unit cell, minus the engine (shared)
/// and the fabric-global state.
struct NodeCell {
    dcs: Dcs,
    /// Full global backing image. Only the stripe this node homes is
    /// authoritative; chase pointers (never rewritten) are valid
    /// everywhere.
    mem: MemStore,
    dram: Dram,
    kvs: KvsService,
    remote: RemoteAgent,
    cache: Cache,
    /// Client -> local home slice (requests).
    to_home: FramedIngress,
    /// Local home slice -> client (responses).
    to_cpu: FramedIngress,
    arrivals: Arrivals,
    traffic_rng: Rng,
    sampler: TrafficSampler,
    /// Arrivals this node generates (its share of the fabric total).
    quota: u64,
    ops: Vec<OpCtx>,
    free: Vec<u32>,
    waiters: HashMap<LineAddr, Vec<u32>>,
    chase_ids: HashSet<u32>,
    issued: u64,
    completed: u64,
    poll_at: Vec<Time>,
    peak_in_flight: u32,
    retx_pending: [bool; 2],
    retx_seen_acked: [u64; 2],
    ack_flush_pending: [bool; 2],
    /// Per-(slice, vc) provenance of admitted messages, matched by line
    /// address at service time (see [`Source`]).
    prov: HashMap<(usize, u8), VecDeque<(LineAddr, Source)>>,
    lat: Histogram,
    /// Inter-node hop latency of frames landing at this node.
    hop_lat: Histogram,
    counters: Counters,
}

/// One ordered node pair's fabric link: requests src -> dst, responses
/// dst -> src, each a full framed/credit/rel ingress.
struct FabChan {
    src: u8,
    dst: u8,
    req: FramedIngress,
    rsp: FramedIngress,
    /// Per-direction rel-link timer state (0 = req, 1 = rsp).
    retx_pending: [bool; 2],
    retx_seen_acked: [u64; 2],
    ack_flush_pending: [bool; 2],
    /// Consecutive forced replays with no ack progress, per direction —
    /// the failure detector's evidence that the peer has gone silent.
    barren: [u32; 2],
}

/// Consecutive barren retransmissions on one channel direction before
/// the transmitter suspects its peer is dead.
const DEAD_RETX_SUSPECT: u32 = 8;

enum Ev {
    // -- node-local (the open-loop cell, node-tagged) --
    Arrive(u8),
    Step(u8, u32),
    LandHome(u8, Box<Frame>),
    LandCpu(u8, Box<Frame>),
    HomeSend(u8, Box<Message>),
    CtlHome(u8, Control),
    CtlCpu(u8, Control),
    CreditHome(u8, VcId),
    CreditCpu(u8, VcId),
    Poll(u8, u32),
    RetxHome(u8),
    RetxCpu(u8),
    AckFlushHome(u8),
    AckFlushCpu(u8),
    // -- fabric channels (chan-index-tagged) --
    FabLandReq(u16, Box<Frame>),
    FabLandRsp(u16, Box<Frame>),
    /// A home-side response is ready for a channel's return direction.
    FabSendRsp(u16, Box<Message>),
    FabCtlReq(u16, Control),
    FabCtlRsp(u16, Control),
    FabCreditReq(u16, VcId),
    FabCreditRsp(u16, VcId),
    FabRetxReq(u16),
    FabRetxRsp(u16),
    FabAckFlushReq(u16),
    FabAckFlushRsp(u16),
    /// Hand a message (original id restored) from node `2` to home `0`
    /// directly: parked-request re-injection after a migration commits
    /// or aborts, post-commit races chasing a moved line, and failover
    /// replay/reclaim injections.
    FabInject(u8, Box<Message>, u8),
    /// Scripted whole-node failure: the node goes dark now.
    Kill(u8),
    /// Watchdog deadline for a killed node: declare it dead if the
    /// retransmission detector has not already.
    FailCheck(u8),
}

use crate::workload::arrival::Arrivals;

fn chan_idx(src: u8, dst: u8, nodes: u8) -> u16 {
    debug_assert_ne!(src, dst, "no self-channel");
    src as u16 * nodes as u16 + dst as u16
}

/// Bit position of the node id inside a span key (trace exporters pass
/// this to [`crate::obs::chrome::build`] to recover the node track).
pub const SPAN_NODE_SHIFT: u32 = 26;

/// Span-tracer keys must be fabric-unique: node in the top bits, the
/// client's transaction id below. With one node this is the identity
/// map, so 1-node fabric waterfalls match open-loop ones exactly.
fn span_key(node: u8, id: u32) -> u32 {
    debug_assert_eq!(id & 0xFC00_0000, 0, "client ids stay below 2^26");
    ((node as u32) << SPAN_NODE_SHIFT) | id
}

/// Per-node span-sampling phases, derived from the run seed so they are
/// deterministic yet uncorrelated with the arrival process. Nodes issue
/// in near-lockstep (same arrival config), so identical phases would
/// sample the *same* global positions on every node; pairwise-distinct
/// phases (enforced by linear probing while distinct residues remain)
/// spread the 1-in-N samples across the fabric's issue interleaving.
pub fn span_phases(seed: u64, nodes: u8, every: u32) -> Vec<u32> {
    let every = every.max(1);
    let mut out: Vec<u32> = Vec::with_capacity(nodes as usize);
    for node in 0..nodes as u64 {
        let mut p = (stream_seed(seed, 3, node, 0) % every as u64) as u32;
        // only probe while distinct residues remain (nodes > every wraps)
        while out.len() < every as usize && out.contains(&p) {
            p = (p + 1) % every;
        }
        out.push(p);
    }
    out
}

/// The N-node fabric host: N open-loop cells on one event engine,
/// joined by framed inter-node channels, a global interleave, and the
/// migration machinery.
pub struct Fabric {
    cfg: FabricConfig,
    scenario_name: String,
    eng: Engine<Ev>,
    nodes: Vec<NodeCell>,
    /// Dense N x N, `None` on the diagonal; index = src * N + dst.
    chans: Vec<Option<FabChan>>,
    interleave: Interleave,
    xlat: IdTranslator,
    mig: Migrator,
    /// Last node granted each line (routes home-initiated `Fwd*` to the
    /// holder).
    granted_to: HashMap<LineAddr, u8>,
    /// Lines per node's traffic window (class windows back to back).
    window_lines: u64,
    /// Total lines across all windows.
    region_lines: u64,
    completed_total: u64,
    /// Ops the fabric still owes: the configured total minus the dead
    /// node's abandoned quota once a kill is declared.
    target_ops: u64,
    /// Scripted kill fired: (node, when it went dark).
    killed: Option<(u8, Time)>,
    /// Survivors declared the kill: (node, when).
    dead_declared: Option<(u8, Time)>,
    /// Messages bound for a killed-but-undeclared home, held until the
    /// declaration re-homes their lines.
    limbo: Vec<(Message, u8)>,
    /// Fabric-side mirror of remote-held possession epochs per
    /// (line, holder node), holder != home. Read once at declaration to
    /// close the grants the dead node still held, then frozen.
    epochs: HashMap<(LineAddr, u8), u32>,
    kill_stats: KillStats,
    /// Completion timestamps (kill runs only) for the goodput timeline.
    completion_ps: Vec<u64>,
    scratch: Vec<(Time, Frame)>,
    rx_frames: Vec<Frame>,
    rx_ctls: Vec<Control>,
    obs: Option<Obs>,
}

#[derive(Default)]
struct KillStats {
    rehomed: u64,
    replayed: u64,
    reclaimed: u64,
    dropped_requests: u64,
    dropped_responses: u64,
    abandoned_ops: u64,
}

impl Fabric {
    pub fn new(cfg: FabricConfig, scenario: &Scenario) -> Fabric {
        assert!(cfg.nodes >= 1, "fabric needs at least one node");
        assert!(cfg.slices > 0, "need at least one slice per node");
        assert!(cfg.ol.ops > 0, "need at least one arrival");
        assert!(
            !(cfg.migrate && cfg.ol.cached),
            "home migration requires streaming clients: a caching client \
             never releases its lines, so a mid-move line would never quiesce"
        );
        if let Some(k) = cfg.kill {
            assert!(k.node < cfg.nodes, "kill target out of range");
            assert!(cfg.nodes >= 2, "killing the only node leaves no survivors");
        }
        let n = cfg.nodes as u64;
        let mut master = Rng::new(cfg.ol.seed);
        let spec = reference_transitions();

        let window = scenario.total_lines();
        assert!(window >= 2, "scenario region too small");
        let region = window * n;

        // Pass 1: everything that draws on the master RNG, node-major in
        // the exact open-loop order (shuffle, sampler, links, arrivals,
        // traffic). With one node this is bit-identical to
        // `OpenLoop::new`, which is what the 1-node equivalence gate
        // checks end to end.
        struct Proto {
            chain: Vec<u64>,
            sampler: TrafficSampler,
            to_home: FramedIngress,
            to_cpu: FramedIngress,
            arrivals: Arrivals,
            traffic_rng: Rng,
        }
        let mut protos: Vec<Proto> = Vec::with_capacity(cfg.nodes as usize);
        for node in 0..n {
            let mut chain: Vec<u64> = (0..window).collect();
            master.shuffle(&mut chain);
            let sampler = TrafficSampler::build(scenario, &mut master);
            // every link direction draws a provably disjoint fault
            // stream: kind 1 = node<->client links, indexed by node
            // (kind 2 below = inter-node channels). The old affine
            // `seed + 2*node(+1)` scheme let different link families
            // collide on one seed and replay correlated fault patterns.
            let to_home = match cfg.ol.machine.rel {
                Some(mut rc) => {
                    rc.faults.seed = stream_seed(rc.faults.seed, 1, node, 0);
                    FramedIngress::with_rel(cfg.ol.machine.link, Node::Remote, master.fork(2), rc)
                }
                None => FramedIngress::new(cfg.ol.machine.link, Node::Remote, master.fork(2)),
            };
            let to_cpu = match cfg.ol.machine.rel {
                Some(mut rc) => {
                    rc.faults.seed = stream_seed(rc.faults.seed, 1, node, 1);
                    FramedIngress::with_rel(cfg.ol.machine.link, Node::Home, master.fork(3), rc)
                }
                None => FramedIngress::new(cfg.ol.machine.link, Node::Home, master.fork(3)),
            };
            let arrivals = Arrivals::new(cfg.ol.arrivals, cfg.ol.rate_per_s, master.fork(4));
            let traffic_rng = master.fork(5);
            protos.push(Proto { chain, sampler, to_home, to_cpu, arrivals, traffic_rng });
        }

        // Fabric channels draw after all nodes (a 1-node fabric builds
        // none, leaving the stream untouched).
        let mut chans: Vec<Option<FabChan>> = Vec::with_capacity((n * n) as usize);
        for s in 0..cfg.nodes {
            for d in 0..cfg.nodes {
                if s == d {
                    chans.push(None);
                    continue;
                }
                let c = s as u64 * n + d as u64;
                let req = match cfg.ol.machine.rel {
                    Some(mut rc) => {
                        rc.faults.seed = stream_seed(rc.faults.seed, 2, c, 0);
                        FramedIngress::with_rel(
                            cfg.ol.machine.link,
                            Node::Remote,
                            master.fork(1000 + 2 * c),
                            rc,
                        )
                    }
                    None => {
                        FramedIngress::new(cfg.ol.machine.link, Node::Remote, master.fork(1000 + 2 * c))
                    }
                };
                let rsp = match cfg.ol.machine.rel {
                    Some(mut rc) => {
                        rc.faults.seed = stream_seed(rc.faults.seed, 2, c, 1);
                        FramedIngress::with_rel(
                            cfg.ol.machine.link,
                            Node::Home,
                            master.fork(1000 + 2 * c + 1),
                            rc,
                        )
                    }
                    None => FramedIngress::new(
                        cfg.ol.machine.link,
                        Node::Home,
                        master.fork(1000 + 2 * c + 1),
                    ),
                };
                chans.push(Some(FabChan {
                    src: s,
                    dst: d,
                    req,
                    rsp,
                    retx_pending: [false; 2],
                    retx_seen_acked: [0; 2],
                    ack_flush_pending: [false; 2],
                    barren: [0; 2],
                }));
            }
        }

        // Global backing image: node m's window holds lines
        // [m*window, (m+1)*window); chase chains stay inside their
        // window (pointer = m*window + chain_m[i]).
        let mut image: Vec<[u8; 128]> = Vec::with_capacity(region as usize);
        for (m, p) in protos.iter().enumerate() {
            for i in 0..window {
                let g = m as u64 * window + i;
                let mut line = [0u8; 128];
                line[0..8].copy_from_slice(&g.to_le_bytes());
                line[120..128]
                    .copy_from_slice(&(m as u64 * window + p.chain[i as usize]).to_le_bytes());
                image.push(line);
            }
        }

        let quota_base = cfg.ol.ops / n;
        let quota_rem = cfg.ol.ops % n;
        let mut cells: Vec<NodeCell> = Vec::with_capacity(cfg.nodes as usize);
        for (idx, p) in protos.into_iter().enumerate() {
            let mut mem = MemStore::new(LineAddr(0), (region as usize) * 128);
            for (g, line) in image.iter().enumerate() {
                mem.write_line(LineAddr(g as u64), line);
            }
            let dcs_cfg = if cfg.ol.home_cached {
                cfg.ol.machine.dcs_cached_config(cfg.slices)
            } else {
                cfg.ol.machine.dcs_config(cfg.slices)
            };
            cells.push(NodeCell {
                dcs: Dcs::with_reference_rules(dcs_cfg),
                mem,
                dram: Dram::new(cfg.ol.machine.fpga_dram),
                kvs: KvsService::new(cfg.ol.kvs_engines),
                remote: RemoteAgent::new(Node::Remote, generate_remote(&spec), LineAddr(0), region),
                cache: Cache::new(cfg.ol.machine.cpu.llc_bytes, cfg.ol.machine.cpu.llc_ways),
                to_home: p.to_home,
                to_cpu: p.to_cpu,
                arrivals: p.arrivals,
                traffic_rng: p.traffic_rng,
                sampler: p.sampler,
                quota: quota_base + u64::from((idx as u64) < quota_rem),
                ops: Vec::new(),
                free: Vec::new(),
                waiters: HashMap::default(),
                chase_ids: HashSet::default(),
                issued: 0,
                completed: 0,
                poll_at: vec![Time::ZERO; cfg.slices],
                peak_in_flight: 0,
                retx_pending: [false; 2],
                retx_seen_acked: [0; 2],
                ack_flush_pending: [false; 2],
                prov: HashMap::default(),
                lat: Histogram::new(),
                hop_lat: Histogram::new(),
                counters: Counters::new(),
            });
        }

        Fabric {
            scenario_name: scenario.name.clone(),
            eng: Engine::new(),
            nodes: cells,
            chans,
            interleave: Interleave::new(cfg.nodes),
            xlat: IdTranslator::new(),
            mig: Migrator::new(),
            granted_to: HashMap::default(),
            window_lines: window,
            region_lines: region,
            completed_total: 0,
            target_ops: cfg.ol.ops,
            killed: None,
            dead_declared: None,
            limbo: Vec::new(),
            epochs: HashMap::default(),
            kill_stats: KillStats::default(),
            completion_ps: Vec::new(),
            scratch: Vec::new(),
            rx_frames: Vec::new(),
            rx_ctls: Vec::new(),
            obs: None,
            cfg,
        }
    }

    /// Attach passive observability before running (span tracing and/or
    /// the telemetry ticker); collect through [`Fabric::run_observed`]
    /// or [`Fabric::run_settled_observed`].
    pub fn with_obs(mut self, ocfg: &ObsConfig) -> Fabric {
        if ocfg.enabled() {
            // multi-node runs decorrelate span sampling across cells
            // (see `span_phases`); 1-node runs keep phase 0 so their
            // waterfall stays bit-identical to the open-loop host's
            if ocfg.spans && ocfg.span_phases.is_empty() && self.cfg.nodes > 1 {
                let mut derived = ocfg.clone();
                derived.span_phases = span_phases(
                    self.cfg.ol.seed,
                    self.cfg.nodes,
                    ocfg.span_sample_every.max(1),
                );
                self.obs = Some(Obs::new(&derived));
            } else {
                self.obs = Some(Obs::new(ocfg));
            }
        }
        self
    }

    /// Run until every arrival on every node has completed.
    pub fn run(mut self) -> FabricReport {
        self.run_to_completion();
        self.report()
    }

    /// Run to completion, settle every trailing event (releases,
    /// replays, credit returns, parked re-injections), and digest the
    /// final global state: for every line, the *home* node's directory
    /// state and backing bytes. On one node this digest is computed
    /// exactly as [`crate::workload::OpenLoop::run_settled`] computes
    /// its own.
    pub fn run_settled(mut self) -> (FabricReport, u64) {
        let digest = self.settle();
        (self.report(), digest)
    }

    pub fn run_observed(mut self) -> (FabricReport, ObsReport) {
        self.run_to_completion();
        let obs = self.finish_obs();
        (self.report(), obs)
    }

    pub fn run_settled_observed(mut self) -> (FabricReport, u64, ObsReport) {
        let digest = self.settle();
        let obs = self.finish_obs();
        (self.report(), digest, obs)
    }

    fn settle(&mut self) -> u64 {
        self.run_to_completion();
        while let Some((_, ev)) = self.eng.pop() {
            self.dispatch(ev);
            self.obs_tick();
        }
        debug_assert_eq!(self.mig.in_flight(), 0, "settled with a migration mid-move");
        debug_assert_eq!(self.xlat.pending(), 0, "settled with unresolved forwarded ids");
        debug_assert!(self.limbo.is_empty(), "settled with messages limboed at a dead home");
        self.state_digest()
    }

    fn run_to_completion(&mut self) {
        for node in 0..self.cfg.nodes {
            if self.nodes[node as usize].quota > 0 {
                self.eng.schedule(Duration::ZERO, Ev::Arrive(node));
            }
        }
        if let Some(k) = self.cfg.kill {
            self.eng.schedule(k.at, Ev::Kill(k.node));
        }
        while self.completed_total < self.target_ops {
            let Some((_, ev)) = self.eng.pop() else {
                let per: Vec<(u64, u64, usize)> = self
                    .nodes
                    .iter()
                    .map(|c| (c.completed, c.quota, c.dcs.pending()))
                    .collect();
                // a dead node is an explained stall; an empty queue short
                // of target with no kill in play is a stuck protocol
                let failure = match (self.killed, self.dead_declared) {
                    (Some((n, at)), None) => {
                        format!(" [node {n} killed at {at:?}, death NOT yet declared]")
                    }
                    (_, Some((n, at))) => {
                        format!(" [node {n} dead (declared at {at:?}), survivors stuck]")
                    }
                    _ => String::new(),
                };
                // post-mortem: dump the flight recorder *before*
                // unwinding so the stuck run leaves evidence behind
                if let Some(fl) = self.obs.as_mut().and_then(|o| o.flight.as_mut()) {
                    let dump = fl.dump_string("deadlock", self.eng.now());
                    match self.obs.as_ref().and_then(|o| o.flight_path.as_deref()) {
                        Some(path) => {
                            let _ = std::fs::write(path, format!("[{dump}]\n"));
                            eprintln!("flight recorder dumped to {path}");
                        }
                        None => eprintln!("flight recorder: {dump}"),
                    }
                }
                panic!(
                    "fabric deadlock: {} of {} ops complete, {} moves in flight, \
                     per-node (completed, quota, dcs-pending) {:?}{}",
                    self.completed_total,
                    self.target_ops,
                    self.mig.in_flight(),
                    per,
                    failure
                );
            };
            self.dispatch(ev);
            self.obs_tick();
        }
    }

    fn obs_tick(&mut self) {
        let now = self.eng.now();
        if !self.obs.as_ref().is_some_and(|o| o.tick_due(now)) {
            return;
        }
        let mut obs = self.obs.take().expect("checked above");
        self.refresh_registry(&mut obs.registry);
        if let Some(sp) = &obs.spans {
            obs.registry.gauge("obs.live_spans", sp.live_spans() as f64);
        }
        obs.tick(now);
        self.obs = Some(obs);
    }

    /// Absorb every node's counter surfaces under `node<N>.`-prefixed
    /// dotted names (no collisions across nodes), plus the fabric
    /// channels and the merged rel-link stats.
    fn refresh_registry(&self, reg: &mut Registry) {
        reg.begin_refresh();
        let mut rel = None;
        let mut eat_rel = |ing: &FramedIngress, rel: &mut Option<crate::transport::rel::RelStats>| {
            if let Some(s) = ing.rel_stats() {
                match rel {
                    Some(acc) => acc.merge(&s),
                    None => *rel = Some(s),
                }
            }
        };
        for (i, cell) in self.nodes.iter().enumerate() {
            reg.absorb(&format!("node{i}.workload"), &cell.counters);
            reg.set(&format!("node{i}.workload.issued"), cell.issued);
            reg.set(&format!("node{i}.workload.completed"), cell.completed);
            reg.set(&format!("node{i}.workload.kvs_lookups"), cell.kvs.served);
            reg.absorb(&format!("node{i}.dcs"), &cell.dcs.counters());
            cell.dcs.observe_gauges(&format!("node{i}.dcs"), reg);
            cell.to_home.observe(&format!("node{i}.ingress.to_home"), reg);
            cell.to_cpu.observe(&format!("node{i}.ingress.to_cpu"), reg);
            eat_rel(&cell.to_home, &mut rel);
            eat_rel(&cell.to_cpu, &mut rel);
        }
        for ch in self.chans.iter().flatten() {
            let (s, d) = (ch.src, ch.dst);
            ch.req.observe(&format!("node{s}.flink{d}.req"), reg);
            ch.rsp.observe(&format!("node{s}.flink{d}.rsp"), reg);
            eat_rel(&ch.req, &mut rel);
            eat_rel(&ch.rsp, &mut rel);
        }
        reg.set("fabric.moved_lines", self.interleave.moved_lines() as u64);
        reg.set("fabric.migrations_in_flight", self.mig.in_flight() as u64);
        reg.set("fabric.ids_pending", self.xlat.pending() as u64);
        if self.cfg.kill.is_some() {
            for i in 0..self.nodes.len() {
                let dead = matches!(self.dead_declared, Some((n, _)) if n as usize == i);
                reg.gauge(&format!("node{i}.dead"), if dead { 1.0 } else { 0.0 });
            }
            reg.set("fabric.rehomed_lines", self.kill_stats.rehomed);
            reg.set("fabric.replayed_requests", self.kill_stats.replayed);
            reg.set("fabric.reclaimed_epochs", self.kill_stats.reclaimed);
        }
        if let Some(s) = rel {
            reg.absorb_rel("rel", &s);
        }
    }

    fn finish_obs(&mut self) -> ObsReport {
        let mut obs = self.obs.take().expect("attach obs with with_obs first");
        self.refresh_registry(&mut obs.registry);
        obs.tick(self.eng.now());
        obs.finish_at(self.eng.now())
    }

    /// FNV-1a over every line's directory state *at its home node* and
    /// that node's backing bytes.
    fn state_digest(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat = |h: &mut u64, b: u8| {
            *h ^= b as u64;
            *h = h.wrapping_mul(FNV_PRIME);
        };
        for i in 0..self.region_lines {
            let addr = LineAddr(i);
            let home = self.interleave.home_of(addr) as usize;
            for b in format!("{:?}", self.nodes[home].dcs.state_of(addr)).bytes() {
                eat(&mut h, b);
            }
            for &b in self.nodes[home].mem.read_line(addr).iter() {
                eat(&mut h, b);
            }
        }
        h
    }

    /// Should this event be silently discarded because a killed node is
    /// on its path? The dead cell's own events always drop; channel
    /// events with a dead endpoint drop *except* the surviving
    /// transmitter's retransmission timers before the declaration —
    /// those ARE the failure detector. `FabInject` routes around death
    /// inside its handler, and the kill/watchdog events always run.
    fn gated_by_death(&self, ev: &Ev) -> bool {
        let Some((p, _)) = self.killed else { return false };
        let declared = self.dead_declared.is_some();
        let touches = |c: u16| {
            let ch = self.chans[c as usize].as_ref().expect("off-diagonal");
            ch.src == p || ch.dst == p
        };
        match ev {
            Ev::Kill(_) | Ev::FailCheck(_) | Ev::FabInject(..) => false,
            Ev::Arrive(n)
            | Ev::Step(n, _)
            | Ev::LandHome(n, _)
            | Ev::LandCpu(n, _)
            | Ev::HomeSend(n, _)
            | Ev::CtlHome(n, _)
            | Ev::CtlCpu(n, _)
            | Ev::CreditHome(n, _)
            | Ev::CreditCpu(n, _)
            | Ev::Poll(n, _)
            | Ev::RetxHome(n)
            | Ev::RetxCpu(n)
            | Ev::AckFlushHome(n)
            | Ev::AckFlushCpu(n) => *n == p,
            Ev::FabLandReq(c, _)
            | Ev::FabLandRsp(c, _)
            | Ev::FabSendRsp(c, _)
            | Ev::FabCtlReq(c, _)
            | Ev::FabCtlRsp(c, _)
            | Ev::FabCreditReq(c, _)
            | Ev::FabCreditRsp(c, _)
            | Ev::FabAckFlushReq(c)
            | Ev::FabAckFlushRsp(c) => touches(*c),
            Ev::FabRetxReq(c) => {
                touches(*c)
                    && (declared
                        || self.chans[*c as usize].as_ref().expect("off-diagonal").src == p)
            }
            Ev::FabRetxRsp(c) => {
                touches(*c)
                    && (declared
                        || self.chans[*c as usize].as_ref().expect("off-diagonal").dst == p)
            }
        }
    }

    fn dispatch(&mut self, ev: Ev) {
        if self.gated_by_death(&ev) {
            return;
        }
        match ev {
            Ev::Arrive(n) => self.arrive(n),
            Ev::Step(n, s) => self.step(n, s),
            Ev::LandHome(n, f) => self.land_home(n, f),
            Ev::LandCpu(n, f) => self.land_cpu(n, f),
            Ev::HomeSend(n, m) => {
                self.nodes[n as usize].to_cpu.offer(*m);
                self.pump_cpu(n);
            }
            Ev::CtlHome(n, c) => {
                let now = self.eng.now();
                self.nodes[n as usize].to_home.on_control(now, c);
                self.pump_home(n);
            }
            Ev::CtlCpu(n, c) => {
                let now = self.eng.now();
                self.nodes[n as usize].to_cpu.on_control(now, c);
                self.pump_cpu(n);
            }
            Ev::CreditHome(n, vc) => {
                self.nodes[n as usize].to_home.credit_return(vc);
                self.pump_home(n);
            }
            Ev::CreditCpu(n, vc) => {
                self.nodes[n as usize].to_cpu.credit_return(vc);
                self.pump_cpu(n);
            }
            Ev::Poll(n, s) => self.pump_slice(n, s as usize),
            Ev::RetxHome(n) => self.on_retx(n, 0),
            Ev::RetxCpu(n) => self.on_retx(n, 1),
            Ev::AckFlushHome(n) => self.on_ack_flush(n, 0),
            Ev::AckFlushCpu(n) => self.on_ack_flush(n, 1),
            Ev::FabLandReq(c, f) => self.fab_land_req(c, f),
            Ev::FabLandRsp(c, f) => self.fab_land_rsp(c, f),
            Ev::FabSendRsp(c, m) => {
                self.chans[c as usize].as_mut().expect("off-diagonal").rsp.offer(*m);
                self.pump_chan(c, 1);
            }
            Ev::FabCtlReq(c, ctl) => {
                let now = self.eng.now();
                self.chans[c as usize].as_mut().expect("off-diagonal").req.on_control(now, ctl);
                self.pump_chan(c, 0);
            }
            Ev::FabCtlRsp(c, ctl) => {
                let now = self.eng.now();
                self.chans[c as usize].as_mut().expect("off-diagonal").rsp.on_control(now, ctl);
                self.pump_chan(c, 1);
            }
            Ev::FabCreditReq(c, vc) => {
                self.chans[c as usize].as_mut().expect("off-diagonal").req.credit_return(vc);
                self.pump_chan(c, 0);
            }
            Ev::FabCreditRsp(c, vc) => {
                self.chans[c as usize].as_mut().expect("off-diagonal").rsp.credit_return(vc);
                self.pump_chan(c, 1);
            }
            Ev::FabRetxReq(c) => self.on_chan_retx(c, 0),
            Ev::FabRetxRsp(c) => self.on_chan_retx(c, 1),
            Ev::FabAckFlushReq(c) => self.on_chan_ack_flush(c, 0),
            Ev::FabAckFlushRsp(c) => self.on_chan_ack_flush(c, 1),
            Ev::FabInject(h, m, src) => self.fab_inject(h, *m, src),
            Ev::Kill(n) => self.on_kill(n),
            Ev::FailCheck(n) => {
                if self.dead_declared.is_none() {
                    self.declare_dead(n);
                }
            }
        }
    }

    // -- arrivals -----------------------------------------------------------

    fn arrive(&mut self, n: u8) {
        if self.nodes[n as usize].issued >= self.nodes[n as usize].quota {
            return;
        }
        self.spawn(n);
        let cell = &mut self.nodes[n as usize];
        if cell.issued < cell.quota {
            let gap = cell.arrivals.next_gap();
            self.eng.schedule(gap, Ev::Arrive(n));
        }
    }

    fn spawn(&mut self, n: u8) {
        let now = self.eng.now();
        let base = n as u64 * self.window_lines;
        let cell = &mut self.nodes[n as usize];
        let (_, kind, line) = cell.sampler.sample(&mut cell.traffic_rng);
        let kind = match kind {
            SampleKind::Read => OpKind::Read,
            SampleKind::Write => OpKind::Write,
            SampleKind::Chase { hops } => OpKind::Chase { left: hops },
        };
        // each node draws inside its own window: windows are disjoint,
        // so every line has exactly one *talker* — but its home is
        // wherever the interleave puts it
        let ctx = OpCtx { kind, addr: LineAddr(base + line), started: now, active: true };
        let slot = match cell.free.pop() {
            Some(s) => {
                cell.ops[s as usize] = ctx;
                s
            }
            None => {
                cell.ops.push(ctx);
                (cell.ops.len() - 1) as u32
            }
        };
        cell.issued += 1;
        self.step(n, slot);
    }

    // -- client side --------------------------------------------------------

    /// Single admission point for node `n`'s client traffic toward its
    /// local home hop (span stage `Issue`). Each node is its own issue
    /// stream: the tracer's per-stream phases keep multi-node sampling
    /// from locking onto the same arrival ordinals on every cell.
    fn offer_home(&mut self, n: u8, m: Message) {
        if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
            if let MsgKind::CohReq { op } = &m.kind {
                if op.needs_response() {
                    sp.on_issue_stream(self.eng.now(), span_key(n, m.id.0), n as usize);
                }
            }
        }
        self.nodes[n as usize].to_home.offer(m);
    }

    fn step(&mut self, n: u8, slot: u32) {
        let (addr, write, is_chase) = {
            let o = &self.nodes[n as usize].ops[slot as usize];
            debug_assert!(o.active, "step on a completed op slot");
            (o.addr, matches!(o.kind, OpKind::Write), matches!(o.kind, OpKind::Chase { .. }))
        };
        let (acc, fx) = {
            let cell = &mut self.nodes[n as usize];
            cell.remote.local_access(addr, write, &mut cell.cache)
        };
        let mut sent = false;
        for e in fx {
            match e {
                RemoteEffect::Send(m) => {
                    if is_chase {
                        if let MsgKind::CohReq { op } = &m.kind {
                            if op.needs_response() {
                                self.nodes[n as usize].chase_ids.insert(m.id.0);
                            }
                        }
                    }
                    self.offer_home(n, m);
                    sent = true;
                }
                RemoteEffect::Stalled => {}
                RemoteEffect::Filled { .. } => {}
                RemoteEffect::ForeignVictim(_) => {
                    self.nodes[n as usize].counters.inc("foreign_victim")
                }
            }
        }
        if sent {
            self.pump_home(n);
        }
        match acc {
            Access::Hit => self.access_done(n, slot),
            Access::Pending => {
                let cell = &mut self.nodes[n as usize];
                cell.waiters.entry(addr).or_default().push(slot);
                if !sent {
                    cell.counters.inc("mshr_merged");
                }
            }
        }
    }

    fn access_done(&mut self, n: u8, slot: u32) {
        let now = self.eng.now();
        let (kind, addr) = {
            let o = &self.nodes[n as usize].ops[slot as usize];
            (o.kind, o.addr)
        };
        match kind {
            OpKind::Write => {
                if let Some(e) = self.nodes[n as usize].cache.lookup(addr) {
                    e.data[0..8].copy_from_slice(&now.ps().to_le_bytes());
                }
                self.finish(n, slot, addr);
            }
            OpKind::Read => self.finish(n, slot, addr),
            OpKind::Chase { left } => {
                if left <= 1 {
                    self.finish(n, slot, addr);
                    return;
                }
                let data = {
                    let cell = &mut self.nodes[n as usize];
                    // chase pointers (bytes 120..128) are never
                    // rewritten, so even a node's stale copy of a
                    // remote-homed line decodes the right next hop
                    cell.cache
                        .peek(addr)
                        .map(|e| *e.data)
                        .unwrap_or_else(|| cell.mem.read_line(addr))
                };
                let ptr = u64::from_le_bytes(data[120..128].try_into().unwrap());
                if !self.cfg.ol.cached {
                    self.release(n, addr);
                }
                let o = &mut self.nodes[n as usize].ops[slot as usize];
                o.addr = LineAddr(ptr % self.region_lines);
                o.kind = OpKind::Chase { left: left - 1 };
                self.eng.schedule(self.cfg.ol.hop_think, Ev::Step(n, slot));
            }
        }
    }

    fn finish(&mut self, n: u8, slot: u32, addr: LineAddr) {
        let now = self.eng.now();
        {
            let cell = &mut self.nodes[n as usize];
            let started = cell.ops[slot as usize].started;
            cell.lat.record(now.since(started).ps());
            cell.ops[slot as usize].active = false;
            cell.completed += 1;
            cell.free.push(slot);
        }
        self.completed_total += 1;
        if self.cfg.kill.is_some() {
            self.completion_ps.push(now.ps());
        }
        if !self.cfg.ol.cached {
            self.release(n, addr);
        }
    }

    fn release(&mut self, n: u8, addr: LineAddr) {
        let fx = {
            let cell = &mut self.nodes[n as usize];
            cell.remote.evict(addr, &mut cell.cache)
        };
        let mut sent = false;
        for e in fx {
            match e {
                RemoteEffect::Send(m) => {
                    self.offer_home(n, m);
                    sent = true;
                }
                RemoteEffect::Stalled => self.nodes[n as usize].counters.inc("release_deferred"),
                RemoteEffect::Filled { .. } => {}
                RemoteEffect::ForeignVictim(_) => {
                    self.nodes[n as usize].counters.inc("foreign_victim")
                }
            }
        }
        if sent {
            self.nodes[n as usize].counters.inc("released");
            self.pump_home(n);
        }
    }

    fn wake(&mut self, n: u8, addr: LineAddr) {
        let Some(slots) = self.nodes[n as usize].waiters.remove(&addr) else { return };
        for s in slots {
            self.eng.schedule(Duration::ZERO, Ev::Step(n, s));
        }
    }

    // -- node-local link pumping -------------------------------------------

    fn pump_home(&mut self, n: u8) {
        let now = self.eng.now();
        let mut out = std::mem::take(&mut self.scratch);
        {
            let cell = &mut self.nodes[n as usize];
            cell.to_home.steal_piggy_from(&mut cell.to_cpu);
            cell.to_home.pump(now, &mut out);
        }
        for (at, f) in out.drain(..) {
            if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                sp.mark(now, span_key(n, f.msg.id.0), Stage::Launch);
            }
            self.eng.schedule_at(at, Ev::LandHome(n, Box::new(f)));
        }
        self.scratch = out;
        let cell = &mut self.nodes[n as usize];
        cell.peak_in_flight = cell.peak_in_flight.max(cell.to_home.in_flight_total());
        self.arm_retx(n, 0);
    }

    fn pump_cpu(&mut self, n: u8) {
        let now = self.eng.now();
        let mut out = std::mem::take(&mut self.scratch);
        {
            let cell = &mut self.nodes[n as usize];
            cell.to_cpu.steal_piggy_from(&mut cell.to_home);
            cell.to_cpu.pump(now, &mut out);
        }
        for (at, f) in out.drain(..) {
            self.eng.schedule_at(at, Ev::LandCpu(n, Box::new(f)));
        }
        self.scratch = out;
        self.arm_retx(n, 1);
    }

    fn on_retx(&mut self, n: u8, dir: usize) {
        let cell = &mut self.nodes[n as usize];
        cell.retx_pending[dir] = false;
        let ing = if dir == 0 { &mut cell.to_home } else { &mut cell.to_cpu };
        if ing.rel_unacked() == 0 {
            return;
        }
        if ing.rel_acked() == cell.retx_seen_acked[dir] {
            ing.rel_force_replay();
        }
        if dir == 0 {
            self.pump_home(n);
        } else {
            self.pump_cpu(n);
        }
    }

    fn arm_retx(&mut self, n: u8, dir: usize) {
        let cell = &mut self.nodes[n as usize];
        let ing = if dir == 0 { &cell.to_home } else { &cell.to_cpu };
        let Some(rto) = ing.link.rel_rto() else { return };
        if ing.rel_unacked() == 0 || cell.retx_pending[dir] {
            return;
        }
        cell.retx_seen_acked[dir] = ing.rel_acked();
        cell.retx_pending[dir] = true;
        self.eng.schedule(rto, if dir == 0 { Ev::RetxHome(n) } else { Ev::RetxCpu(n) });
    }

    fn on_ack_flush(&mut self, n: u8, dir: usize) {
        self.nodes[n as usize].ack_flush_pending[dir] = false;
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        loop {
            let cell = &mut self.nodes[n as usize];
            let ing = if dir == 0 { &mut cell.to_home } else { &mut cell.to_cpu };
            let Some((vc, seq)) = ing.take_piggy_ack() else { break };
            let ctl = Control::VcAck(vc, seq);
            self.eng
                .schedule(ctrl, if dir == 0 { Ev::CtlHome(n, ctl) } else { Ev::CtlCpu(n, ctl) });
        }
    }

    fn arm_ack_flush(&mut self, n: u8, dir: usize) {
        let cell = &mut self.nodes[n as usize];
        let ing = if dir == 0 { &cell.to_home } else { &cell.to_cpu };
        if cell.ack_flush_pending[dir] || !ing.rel_has_ack_debt() {
            return;
        }
        cell.ack_flush_pending[dir] = true;
        self.eng.schedule(
            crate::transport::rel::ACK_FLUSH_DELAY,
            if dir == 0 { Ev::AckFlushHome(n) } else { Ev::AckFlushCpu(n) },
        );
    }

    // -- routing & admission ------------------------------------------------

    /// A frame from node `n`'s client lands at node `n`'s home hop:
    /// admit it locally if the line homes here, else forward it across
    /// the fabric.
    fn land_home(&mut self, n: u8, frame: Box<Frame>) {
        let now = self.eng.now();
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        let mut delivered = std::mem::take(&mut self.rx_frames);
        let mut ctls = std::mem::take(&mut self.rx_ctls);
        {
            let cell = &mut self.nodes[n as usize];
            if let Some((vc, seq)) = frame.ack {
                cell.to_cpu.on_control(now, Control::VcAck(vc, seq));
            }
            cell.to_home.deliver(*frame, &mut delivered, &mut ctls);
        }
        for c in ctls.drain(..) {
            self.eng.schedule(ctrl, Ev::CtlHome(n, c));
        }
        self.rx_ctls = ctls;
        self.arm_ack_flush(n, 0);
        for f in delivered.drain(..) {
            self.route_local(n, f);
        }
        self.rx_frames = delivered;
    }

    fn route_local(&mut self, n: u8, mut f: Frame) {
        let home = self.interleave.home_of(f.msg.addr);
        if home == n {
            self.admit_frame(n, n, f, Source::Local);
            return;
        }
        // Two-hop path. The local hop is done with this frame: return
        // its credit, translate the id of anything that expects a
        // response (per-node id spaces collide at the remote home), and
        // put the message on the fabric channel.
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        let now = self.eng.now();
        self.eng.schedule(ctrl, Ev::CreditHome(n, f.vc));
        if let MsgKind::CohReq { op } = &f.msg.kind {
            if op.needs_response() && op.initiator() == Node::Remote {
                // the trace context is the (source node, original id)
                // pair the translator carries: mark the span under the
                // pre-translation key — the same key the home-side and
                // landing marks recover through `IdTranslator::peek`.
                if let Some(obs) = self.obs.as_mut() {
                    if let Some(sp) = obs.spans.as_mut() {
                        sp.mark(now, span_key(n, f.msg.id.0), Stage::FwdOut);
                    }
                    obs.flight_record(now, n as u32, FlightKind::FwdOut, f.msg.id.0 as u64, home as u64);
                }
                f.msg.id = self.xlat.translate(n, home, &f.msg);
            }
        }
        self.nodes[n as usize].counters.inc("fab_fwd_out");
        let c = chan_idx(n, home, self.cfg.nodes);
        self.chans[c as usize].as_mut().expect("off-diagonal").req.offer(f.msg);
        self.pump_chan(c, 0);
    }

    /// The migration gate, run on every client-initiated
    /// response-needing request reaching home `h` from node `src`.
    /// Everything else (voluntary downgrades, fwd responses) always
    /// admits — those are the messages a quiescing line is waiting for.
    fn migration_gate(&mut self, h: u8, src: u8, msg: &Message) -> Gate {
        if !self.cfg.migrate {
            return Gate::Admit;
        }
        let addr = msg.addr;
        let MsgKind::CohReq { op } = msg.kind else { return Gate::Admit };
        if !op.needs_response() || op.initiator() != Node::Remote {
            return Gate::Admit;
        }
        if self.mig.target_of(addr).is_some() {
            if matches!(op, CohOp::UpgradeS2E) {
                // the issuer holds the line in S — it can never quiesce
                // while this waits, so the move loses
                self.abort_migration(h, addr);
                // fall through to fresh accounting below
            } else {
                return Gate::Park;
            }
        }
        // An UpgradeS2E may *count* toward the threshold but must never
        // *trigger* a move: parking it while its issuer holds the line
        // in S would block the quiesce it is itself waiting on.
        if self.mig.note(addr, src, h, self.cfg.threshold) && !matches!(op, CohOp::UpgradeS2E) {
            self.mig.begin(addr, src);
            self.nodes[h as usize].counters.inc("fab_migration_begin");
            if let Some(obs) = self.obs.as_mut() {
                let now = self.eng.now();
                obs.flight_record(now, h as u32, FlightKind::MigBegin, addr.0, src as u64);
            }
            // the trigger request parks too: it completes at the new home
            return Gate::Park;
        }
        Gate::Admit
    }

    /// Mirror the home's possession-epoch arithmetic for *remote*
    /// holders as messages are admitted, so a declaration can read off
    /// exactly which grants a dead node still held. Frozen (no-op) once
    /// a death is declared.
    fn ledger_on_admit(&mut self, h: u8, src: u8, msg: &Message) {
        if self.cfg.kill.is_none() || self.dead_declared.is_some() || src == h {
            return;
        }
        let close = match &msg.kind {
            MsgKind::CohReq { op: CohOp::VolDowngradeI } => true,
            MsgKind::CohRsp { op: CohOp::FwdDowngradeI, had_copy, .. } => *had_copy,
            _ => false,
        };
        if close {
            if let Some(k) = self.epochs.get_mut(&(msg.addr, src)) {
                *k = k.saturating_sub(1);
                if *k == 0 {
                    self.epochs.remove(&(msg.addr, src));
                }
            }
        }
    }

    /// Admit a delivered frame into home `h`'s directory (or park it if
    /// the line is mid-move). `src` is the requesting node; `source`
    /// says which transport hop holds the credit.
    fn admit_frame(&mut self, h: u8, src: u8, f: Frame, source: Source) {
        let now = self.eng.now();
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        match self.migration_gate(h, src, &f.msg) {
            Gate::Park => {
                let vc = f.vc;
                let mut msg = f.msg;
                // restore the original id before parking: re-injection
                // happens node-to-node, past the translation point
                let true_src = if IdTranslator::is_translated(msg.id) {
                    let (s0, orig) = self.xlat.resolve(msg.id).expect("translated id pending");
                    msg.id = orig;
                    s0
                } else {
                    src
                };
                let addr = msg.addr;
                if let Some(obs) = self.obs.as_mut() {
                    if let Some(sp) = obs.spans.as_mut() {
                        sp.note_park(span_key(true_src, msg.id.0));
                    }
                    obs.flight_record(now, h as u32, FlightKind::Park, msg.id.0 as u64, addr.0);
                }
                self.mig.park(addr, true_src, msg);
                self.nodes[h as usize].counters.inc("fab_parked");
                // the message left the wire: release the hop's credit
                match source {
                    Source::Local => self.eng.schedule(ctrl, Ev::CreditHome(h, vc)),
                    Source::Chan(c) => self.eng.schedule(ctrl, Ev::FabCreditReq(c, vc)),
                    Source::Parked => {}
                }
                self.try_commit(h, addr);
            }
            Gate::Admit => {
                if self.cfg.migrate {
                    self.mig.live_inc(f.msg.addr);
                }
                self.ledger_on_admit(h, src, &f.msg);
                if let Some(obs) = self.obs.as_mut() {
                    if let Some(sp) = obs.spans.as_mut() {
                        let key = match self.xlat.peek(f.msg.id) {
                            Some((s0, orig)) => span_key(s0, orig.0),
                            None => span_key(src, f.msg.id.0),
                        };
                        sp.mark(now, key, Stage::Deliver);
                    }
                    if src != h {
                        obs.flight_record(
                            now,
                            h as u32,
                            FlightKind::Admit,
                            f.msg.id.0 as u64,
                            src as u64,
                        );
                    }
                }
                let addr = f.msg.addr;
                let vc = f.vc;
                let cell = &mut self.nodes[h as usize];
                let s = cell.dcs.enqueue_frame(now, f);
                cell.prov.entry((s, vc.0)).or_default().push_back((addr, source));
                self.pump_slice(h, s);
            }
        }
    }

    /// Direct message injection at home `h` (parked re-injection,
    /// post-commit races, failover replay/reclaim). The id is the
    /// original; the credit was returned when the message first left
    /// its wire.
    fn fab_inject(&mut self, h: u8, mut msg: Message, src: u8) {
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        let addr = msg.addr;
        // a killed-but-undeclared home cannot admit anything: hold the
        // message until the declaration re-homes its line
        if let Some((p, _)) = self.killed {
            if p == h && self.dead_declared.is_none() {
                self.limbo.push((msg, src));
                return;
            }
            // a dead source's response-needing requests have no
            // requester left to answer — drop them (its voluntary
            // downgrades and fwd responses still admit: the reclaim
            // path speaks for the dead node with exactly those)
            if self.dead_declared.is_some() && src == p {
                if let MsgKind::CohReq { op } = &msg.kind {
                    if op.needs_response() && op.initiator() == Node::Remote {
                        self.kill_stats.dropped_requests += 1;
                        self.nodes[h as usize].counters.inc("fab_dropped_dead_src");
                        return;
                    }
                }
            }
        }
        let home = self.interleave.home_of(addr);
        if home != h {
            // the line moved again while this was in flight: chase it
            self.nodes[h as usize].counters.inc("fab_late_reforward");
            self.eng.schedule(ctrl, Ev::FabInject(home, Box::new(msg), src));
            return;
        }
        match self.migration_gate(h, src, &msg) {
            Gate::Park => {
                if let Some(obs) = self.obs.as_mut() {
                    let now = self.eng.now();
                    if let Some(sp) = obs.spans.as_mut() {
                        sp.note_park(span_key(src, msg.id.0));
                    }
                    obs.flight_record(now, h as u32, FlightKind::Park, msg.id.0 as u64, addr.0);
                }
                self.mig.park(addr, src, msg);
                self.nodes[h as usize].counters.inc("fab_parked");
                self.try_commit(h, addr);
            }
            Gate::Admit => {
                let now = self.eng.now();
                if self.cfg.migrate {
                    self.mig.live_inc(addr);
                }
                self.ledger_on_admit(h, src, &msg);
                if let Some(obs) = self.obs.as_mut() {
                    if let Some(sp) = obs.spans.as_mut() {
                        sp.mark(now, span_key(src, msg.id.0), Stage::Deliver);
                        // every fab_inject admission is a re-injection:
                        // a parked request following a commit/abort, a
                        // post-commit race, or a failover replay
                        sp.note_replay(span_key(src, msg.id.0));
                    }
                    obs.flight_record(now, h as u32, FlightKind::Replay, msg.id.0 as u64, h as u64);
                }
                // a remote source's response-needing request must enter
                // the directory under a translated id, exactly as if it
                // had crossed the fabric — the response routes home by
                // resolving that id (re-injections carry original ids)
                if src != h && !IdTranslator::is_translated(msg.id) {
                    if let MsgKind::CohReq { op } = &msg.kind {
                        if op.needs_response() && op.initiator() == Node::Remote {
                            msg.id = self.xlat.translate(src, h, &msg);
                        }
                    }
                }
                let vc = vc_for(&msg);
                let cell = &mut self.nodes[h as usize];
                let s = cell.dcs.slice_of(addr);
                cell.dcs.enqueue(now, msg);
                cell.prov.entry((s, vc.0)).or_default().push_back((addr, Source::Parked));
                self.pump_slice(h, s);
            }
        }
    }

    // -- home migration -----------------------------------------------------

    /// Commit the move of `addr` away from `h` if the line has fully
    /// quiesced: nothing admitted and un-serviced (live count zero) and
    /// the old home able to surrender — no remote possession, no
    /// pending forward, no stalled events, any dirty home-cache copy
    /// flushed. Called after every park and every serviced message for
    /// the line, so the commit happens at the first quiet instant.
    fn try_commit(&mut self, h: u8, addr: LineAddr) {
        let Some(target) = self.mig.target_of(addr) else { return };
        if self.mig.live(addr) != 0 {
            return;
        }
        if self.cfg.abort_inject {
            // fault injection: the move loses its commit race every
            // time, so every begun migration exercises the abort path
            self.abort_migration(h, addr);
            return;
        }
        let surrendered = {
            let cell = &mut self.nodes[h as usize];
            let (dcs, mem) = (&mut cell.dcs, &mut cell.mem);
            dcs.surrender_local(addr, mem)
        };
        if !surrendered {
            return;
        }
        // handoff: the old home's backing bytes are now authoritative —
        // move them, flip the interleave, re-home the parked requests
        let line = self.nodes[h as usize].mem.read_line(addr);
        self.nodes[target as usize].mem.write_line(addr, &line);
        self.interleave.set_home(addr, target);
        self.granted_to.remove(&addr);
        if let Some(obs) = self.obs.as_mut() {
            let now = self.eng.now();
            obs.flight_record(now, h as u32, FlightKind::MigCommit, addr.0, target as u64);
        }
        self.nodes[h as usize].counters.inc("fab_migrations_out");
        self.nodes[target as usize].counters.inc("fab_migrations_in");
        let parked = self.mig.take_parked(addr);
        self.mig.end(addr);
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        for (src, m) in parked {
            self.eng.schedule(ctrl, Ev::FabInject(target, Box::new(m), src));
        }
    }

    /// Abort the move of `addr` (an `UpgradeS2E` arrived; see
    /// [`Fabric::migration_gate`]): re-inject everything parked at the
    /// *current* home and drop the move state.
    fn abort_migration(&mut self, h: u8, addr: LineAddr) {
        let parked = self.mig.take_parked(addr);
        self.mig.end(addr);
        self.nodes[h as usize].counters.inc("fab_migration_abort");
        if let Some(obs) = self.obs.as_mut() {
            let now = self.eng.now();
            obs.flight_record(now, h as u32, FlightKind::MigAbort, addr.0, h as u64);
        }
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        for (src, m) in parked {
            self.eng.schedule(ctrl, Ev::FabInject(h, Box::new(m), src));
        }
    }

    // -- directory service --------------------------------------------------

    fn pump_slice(&mut self, h: u8, s: usize) {
        let now = self.eng.now();
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        loop {
            let res = {
                let cell = &mut self.nodes[h as usize];
                let (dcs, mem) = (&mut cell.dcs, &mut cell.mem);
                dcs.service_one(s, now, mem)
            };
            match res {
                None => break,
                Some(SliceService::Busy(t)) => {
                    let cell = &mut self.nodes[h as usize];
                    if cell.poll_at[s] < t {
                        cell.poll_at[s] = t;
                        self.eng.schedule_at(t, Ev::Poll(h, s as u32));
                    }
                    break;
                }
                Some(SliceService::Done(ready, vc, addr, fx)) => {
                    let source = {
                        let cell = &mut self.nodes[h as usize];
                        let q = cell
                            .prov
                            .get_mut(&(s, vc.0))
                            .expect("every serviced message was admitted");
                        let i = q
                            .iter()
                            .position(|(a, _)| *a == addr)
                            .expect("provenance recorded at admission");
                        q.remove(i).expect("index from position").1
                    };
                    match source {
                        Source::Local => {
                            self.eng.schedule_at(ready + ctrl, Ev::CreditHome(h, vc))
                        }
                        Source::Chan(c) => {
                            self.eng.schedule_at(ready + ctrl, Ev::FabCreditReq(c, vc))
                        }
                        Source::Parked => {}
                    }
                    if self.cfg.migrate {
                        self.mig.live_dec(addr);
                    }
                    self.handle_effects(h, ready, fx);
                    if self.cfg.migrate {
                        self.try_commit(h, addr);
                    }
                }
            }
        }
    }

    fn handle_effects(&mut self, h: u8, ready: Time, fx: Vec<HomeEffect>) {
        let nodes = self.cfg.nodes;
        for e in fx {
            match e {
                HomeEffect::Respond { mut msg, from_ram } => {
                    // learn who the requester was — without retiring the
                    // translation entry: it retires only when the
                    // response *lands* at the source (fab_land_rsp), so
                    // a response lost with a dying node leaves its
                    // request pending for replay
                    let resolved = if IdTranslator::is_translated(msg.id) {
                        self.xlat.peek(msg.id)
                    } else {
                        Some((h, msg.id))
                    };
                    let Some((src, orig)) = resolved else {
                        // only a swept entry peeks to None: the
                        // requester was declared dead and its pending
                        // ids dropped. Drop the response — and if it
                        // granted a copy, surrender that grant on the
                        // dead node's behalf so the possession epoch the
                        // home just opened closes again.
                        let (p, _) = self
                            .dead_declared
                            .expect("translated id vanished without a declared death");
                        self.kill_stats.dropped_responses += 1;
                        self.nodes[h as usize].counters.inc("fab_rsp_to_dead");
                        if let MsgKind::CohRsp {
                            op: CohOp::ReadShared | CohOp::ReadExclusive, ..
                        } = msg.kind
                        {
                            let give_back = Message::coh_req(
                                ReqId(0),
                                Node::Remote,
                                CohOp::VolDowngradeI,
                                msg.addr,
                            );
                            self.kill_stats.reclaimed += 1;
                            self.eng.schedule_at(
                                ready + self.cfg.ol.machine.ctrl_latency,
                                Ev::FabInject(h, Box::new(give_back), p),
                            );
                        }
                        continue;
                    };
                    let is_chase = self.nodes[src as usize].chase_ids.remove(&orig.0);
                    let addr = msg.addr;
                    let t = {
                        let cell = &mut self.nodes[h as usize];
                        if is_chase {
                            cell.counters.inc("chase_via_kvs");
                            cell.kvs.submit(ready, 1, &mut cell.dram)
                        } else if from_ram {
                            cell.dram.read(ready, addr)
                        } else {
                            ready
                        }
                    };
                    if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                        let proc = self.nodes[h as usize].dcs.cfg.slice_proc.ps();
                        let key = span_key(src, orig.0);
                        sp.mark(Time(ready.ps().saturating_sub(proc)), key, Stage::SvcStart);
                        sp.mark(ready, key, Stage::SvcDone);
                        sp.mark(t, key, Stage::Reply);
                    }
                    msg.id = orig;
                    // ledger: a grant to a remote holder opens a
                    // possession epoch the failover path may later have
                    // to close on the holder's behalf
                    if self.cfg.kill.is_some() && self.dead_declared.is_none() && src != h {
                        if let MsgKind::CohRsp {
                            op: CohOp::ReadShared | CohOp::ReadExclusive, ..
                        } = msg.kind
                        {
                            *self.epochs.entry((addr, src)).or_insert(0) += 1;
                        }
                    }
                    self.granted_to.insert(addr, src);
                    self.nodes[h as usize]
                        .counters
                        .inc(if src == h { "fab_fills_local" } else { "fab_fills_remote" });
                    if src == h {
                        self.eng.schedule_at(t, Ev::HomeSend(h, Box::new(msg)));
                    } else {
                        self.eng
                            .schedule_at(t, Ev::FabSendRsp(chan_idx(src, h, nodes), Box::new(msg)));
                    }
                }
                HomeEffect::Fwd { msg } => {
                    // home-initiated downgrade: route to the last holder
                    let dst = self.granted_to.get(&msg.addr).copied().unwrap_or(h);
                    self.nodes[h as usize].counters.inc("fab_fwds");
                    if dst == h {
                        self.eng.schedule_at(ready, Ev::HomeSend(h, Box::new(msg)));
                    } else {
                        self.eng.schedule_at(
                            ready,
                            Ev::FabSendRsp(chan_idx(dst, h, nodes), Box::new(msg)),
                        );
                    }
                }
                HomeEffect::RamWrite { addr } => {
                    self.nodes[h as usize].dram.write(ready, addr);
                }
                HomeEffect::LocalDone { .. } => {}
            }
        }
    }

    // -- node-local response landing ----------------------------------------

    fn land_cpu(&mut self, n: u8, frame: Box<Frame>) {
        let now = self.eng.now();
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        let mut delivered = std::mem::take(&mut self.rx_frames);
        let mut ctls = std::mem::take(&mut self.rx_ctls);
        {
            let cell = &mut self.nodes[n as usize];
            if let Some((avc, seq)) = frame.ack {
                cell.to_home.on_control(now, Control::VcAck(avc, seq));
            }
            cell.to_cpu.deliver(*frame, &mut delivered, &mut ctls);
        }
        for c in ctls.drain(..) {
            self.eng.schedule(ctrl, Ev::CtlCpu(n, c));
        }
        self.rx_ctls = ctls;
        self.arm_ack_flush(n, 1);
        let mut sent = false;
        let mut fills: Vec<LineAddr> = Vec::new();
        for f in delivered.drain(..) {
            self.eng.schedule(ctrl, Ev::CreditCpu(n, f.vc));
            if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                if matches!(f.msg.kind, MsgKind::CohRsp { .. }) {
                    sp.complete(now, span_key(n, f.msg.id.0));
                }
            }
            let fx = {
                let cell = &mut self.nodes[n as usize];
                cell.remote.on_message(f.msg, &mut cell.cache)
            };
            for e in fx {
                match e {
                    RemoteEffect::Send(m) => {
                        self.offer_home(n, m);
                        sent = true;
                    }
                    RemoteEffect::Filled { addr } => fills.push(addr),
                    RemoteEffect::Stalled => {}
                    RemoteEffect::ForeignVictim(_) => {
                        self.nodes[n as usize].counters.inc("foreign_victim")
                    }
                }
            }
        }
        self.rx_frames = delivered;
        if sent {
            self.pump_home(n);
        }
        for a in fills {
            self.wake(n, a);
        }
    }

    // -- fabric channel pumping ---------------------------------------------

    fn pump_chan(&mut self, c: u16, dir: usize) {
        let now = self.eng.now();
        let mut out = std::mem::take(&mut self.scratch);
        let (src, dst) = {
            let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
            let (tx, rx) =
                if dir == 0 { (&mut ch.req, &mut ch.rsp) } else { (&mut ch.rsp, &mut ch.req) };
            tx.steal_piggy_from(rx);
            tx.pump(now, &mut out);
            (ch.src, ch.dst)
        };
        let landing = if dir == 0 { dst } else { src };
        for (at, f) in out.drain(..) {
            // hop latency accrues to the node the frame lands at —
            // intentionally NOT a span Launch mark: chan pumps re-send
            // translated ids, and retransmit-episode accounting belongs
            // to the client-side link only
            self.nodes[landing as usize].hop_lat.record_dur(at.since(now));
            if let Some(obs) = self.obs.as_mut() {
                if dir == 1 {
                    // the response hop starts here: rsp frames carry the
                    // restored original id and ch.src is the requester
                    if let MsgKind::CohRsp { op, .. } = &f.msg.kind {
                        if op.initiator() == Node::Remote {
                            if let Some(sp) = obs.spans.as_mut() {
                                sp.mark(now, span_key(src, f.msg.id.0), Stage::RspLaunch);
                            }
                        }
                    }
                }
                let tx = if dir == 0 { src } else { dst };
                obs.flight_record(now, tx as u32, FlightKind::ChanLaunch, f.msg.id.0 as u64, c as u64);
            }
            let ev = if dir == 0 {
                Ev::FabLandReq(c, Box::new(f))
            } else {
                Ev::FabLandRsp(c, Box::new(f))
            };
            self.eng.schedule_at(at, ev);
        }
        self.scratch = out;
        self.arm_chan_retx(c, dir);
    }

    /// A forwarded request lands at the far home hop.
    fn fab_land_req(&mut self, c: u16, frame: Box<Frame>) {
        let now = self.eng.now();
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        let mut delivered = std::mem::take(&mut self.rx_frames);
        let mut ctls = std::mem::take(&mut self.rx_ctls);
        let (h, src) = {
            let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
            if let Some((vc, seq)) = frame.ack {
                ch.rsp.on_control(now, Control::VcAck(vc, seq));
            }
            ch.req.deliver(*frame, &mut delivered, &mut ctls);
            (ch.dst, ch.src)
        };
        for ctl in ctls.drain(..) {
            self.eng.schedule(ctrl, Ev::FabCtlReq(c, ctl));
        }
        self.rx_ctls = ctls;
        self.arm_chan_ack_flush(c, 0);
        for f in delivered.drain(..) {
            if let Some(obs) = self.obs.as_mut() {
                obs.flight_record(now, h as u32, FlightKind::ChanLand, f.msg.id.0 as u64, c as u64);
            }
            let home = self.interleave.home_of(f.msg.addr);
            if home == h {
                self.admit_frame(h, src, f, Source::Chan(c));
            } else {
                // the line migrated while this request crossed the
                // fabric: free the channel credit and chase the new home
                self.nodes[h as usize].counters.inc("fab_late_reforward");
                self.eng.schedule(ctrl, Ev::FabCreditReq(c, f.vc));
                let mut msg = f.msg;
                let true_src = if IdTranslator::is_translated(msg.id) {
                    let (s0, orig) = self.xlat.resolve(msg.id).expect("translated id pending");
                    msg.id = orig;
                    s0
                } else {
                    src
                };
                self.eng.schedule(ctrl, Ev::FabInject(home, Box::new(msg), true_src));
            }
        }
        self.rx_frames = delivered;
    }

    /// A response (or home-initiated fwd) lands back at the requesting
    /// node's client.
    fn fab_land_rsp(&mut self, c: u16, frame: Box<Frame>) {
        let now = self.eng.now();
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        let mut delivered = std::mem::take(&mut self.rx_frames);
        let mut ctls = std::mem::take(&mut self.rx_ctls);
        let s = {
            let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
            if let Some((vc, seq)) = frame.ack {
                ch.req.on_control(now, Control::VcAck(vc, seq));
            }
            ch.rsp.deliver(*frame, &mut delivered, &mut ctls);
            ch.src
        };
        for ctl in ctls.drain(..) {
            self.eng.schedule(ctrl, Ev::FabCtlRsp(c, ctl));
        }
        self.rx_ctls = ctls;
        self.arm_chan_ack_flush(c, 1);
        let mut sent = false;
        let mut fills: Vec<LineAddr> = Vec::new();
        for f in delivered.drain(..) {
            self.eng.schedule(ctrl, Ev::FabCreditRsp(c, f.vc));
            if let Some(obs) = self.obs.as_mut() {
                obs.flight_record(now, s as u32, FlightKind::ChanLand, f.msg.id.0 as u64, c as u64);
            }
            if let MsgKind::CohRsp { op, .. } = &f.msg.kind {
                // the response landed at its source: only now does the
                // forwarded transaction's translation entry retire, so
                // "entry pending" always means "source still waiting"
                if op.initiator() == Node::Remote {
                    self.xlat.complete(s, f.msg.id);
                    if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                        sp.complete(now, span_key(s, f.msg.id.0));
                    }
                }
            }
            let fx = {
                let cell = &mut self.nodes[s as usize];
                cell.remote.on_message(f.msg, &mut cell.cache)
            };
            for e in fx {
                match e {
                    RemoteEffect::Send(m) => {
                        self.offer_home(s, m);
                        sent = true;
                    }
                    RemoteEffect::Filled { addr } => fills.push(addr),
                    RemoteEffect::Stalled => {}
                    RemoteEffect::ForeignVictim(_) => {
                        self.nodes[s as usize].counters.inc("foreign_victim")
                    }
                }
            }
        }
        self.rx_frames = delivered;
        if sent {
            self.pump_home(s);
        }
        for a in fills {
            self.wake(s, a);
        }
    }

    fn on_chan_retx(&mut self, c: u16, dir: usize) {
        let mut suspect = None;
        let mut replayed = None;
        {
            let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
            ch.retx_pending[dir] = false;
            let ing = if dir == 0 { &mut ch.req } else { &mut ch.rsp };
            if ing.rel_unacked() == 0 {
                ch.barren[dir] = 0;
                return;
            }
            if ing.rel_acked() == ch.retx_seen_acked[dir] {
                ing.rel_force_replay();
                replayed = Some(if dir == 0 { ch.src } else { ch.dst });
                // no ack progress across a full RTO: evidence the peer
                // has gone silent
                ch.barren[dir] += 1;
                if ch.barren[dir] >= DEAD_RETX_SUSPECT {
                    ch.barren[dir] = 0;
                    suspect = Some(if dir == 0 { ch.dst } else { ch.src });
                }
            } else {
                ch.barren[dir] = 0;
            }
        }
        if let Some(tx) = replayed {
            if let Some(obs) = self.obs.as_mut() {
                let now = self.eng.now();
                obs.flight_record(now, tx as u32, FlightKind::ChanRetx, c as u64, dir as u64);
            }
        }
        if let Some(p) = suspect {
            self.suspect_dead(p);
        }
        self.pump_chan(c, dir);
    }

    /// A channel transmitter accumulated [`DEAD_RETX_SUSPECT`] barren
    /// retransmissions toward `p`. The simulator is omniscient, so a
    /// lone barren link only condemns a node that really was killed —
    /// against a live-but-lossy peer it records a false suspicion
    /// instead (a real deployment would need a quorum here).
    fn suspect_dead(&mut self, p: u8) {
        if self.dead_declared.is_some() {
            return;
        }
        if let Some(obs) = self.obs.as_mut() {
            let now = self.eng.now();
            let real = matches!(self.killed, Some((k, _)) if k == p);
            obs.flight_record(now, p as u32, FlightKind::Suspect, p as u64, real as u64);
        }
        match self.killed {
            Some((k, _)) if k == p => self.declare_dead(p),
            _ => self.nodes[p as usize].counters.inc("fab_false_suspect"),
        }
    }

    fn arm_chan_retx(&mut self, c: u16, dir: usize) {
        let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
        let ing = if dir == 0 { &ch.req } else { &ch.rsp };
        let Some(rto) = ing.link.rel_rto() else { return };
        if ing.rel_unacked() == 0 || ch.retx_pending[dir] {
            return;
        }
        ch.retx_seen_acked[dir] = ing.rel_acked();
        ch.retx_pending[dir] = true;
        self.eng.schedule(rto, if dir == 0 { Ev::FabRetxReq(c) } else { Ev::FabRetxRsp(c) });
    }

    fn on_chan_ack_flush(&mut self, c: u16, dir: usize) {
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        self.chans[c as usize].as_mut().expect("off-diagonal").ack_flush_pending[dir] = false;
        loop {
            let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
            let ing = if dir == 0 { &mut ch.req } else { &mut ch.rsp };
            let Some((vc, seq)) = ing.take_piggy_ack() else { break };
            let ctl = Control::VcAck(vc, seq);
            self.eng.schedule(
                ctrl,
                if dir == 0 { Ev::FabCtlReq(c, ctl) } else { Ev::FabCtlRsp(c, ctl) },
            );
        }
    }

    fn arm_chan_ack_flush(&mut self, c: u16, dir: usize) {
        let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
        let ing = if dir == 0 { &ch.req } else { &ch.rsp };
        if ch.ack_flush_pending[dir] || !ing.rel_has_ack_debt() {
            return;
        }
        ch.ack_flush_pending[dir] = true;
        self.eng.schedule(
            crate::transport::rel::ACK_FLUSH_DELAY,
            if dir == 0 { Ev::FabAckFlushReq(c) } else { Ev::FabAckFlushRsp(c) },
        );
    }

    // -- whole-node failure -------------------------------------------------

    fn on_kill(&mut self, n: u8) {
        assert!(self.killed.is_none(), "one scripted kill per run");
        let now = self.eng.now();
        self.killed = Some((n, now));
        self.nodes[n as usize].counters.inc("fab_killed");
        if let Some(obs) = self.obs.as_mut() {
            obs.flight_record(now, n as u32, FlightKind::Kill, n as u64, 0);
        }
        // watchdog: detection is bounded by cfg.detect even when no
        // retransmission traffic points at the dead node (clean links
        // have no rel timers to starve)
        self.eng.schedule(self.cfg.detect, Ev::FailCheck(n));
    }

    /// Declare node `p` dead. Runs exactly once, atomically inside one
    /// event, from whichever detector fires first (barren channel
    /// retransmissions or the watchdog):
    ///
    /// 1. abandon the dead node's unfinished arrival quota;
    /// 2. cancel migrations touching it (its parked requests drop,
    ///    survivors' parked requests follow their line's new home);
    /// 3. re-interleave its homed lines across the survivors;
    /// 4. rebuild each re-homed line's directory view from survivor
    ///    cache truth (the dead directory's in-flight state is noise);
    /// 5. close the possession epochs the dead node still held at
    ///    surviving homes by speaking for it: answer stalled forwards,
    ///    then surrender each remaining grant;
    /// 6. replay every pending forwarded request a survivor still waits
    ///    on (translation entries retire at response landing, so the
    ///    pending set is exactly the unanswered set — exactly-once);
    /// 7. re-home limboed and saved parked messages.
    fn declare_dead(&mut self, p: u8) {
        debug_assert!(self.dead_declared.is_none(), "death declared twice");
        debug_assert!(
            matches!(self.killed, Some((k, _)) if k == p),
            "declaring a live node dead"
        );
        let now = self.eng.now();
        self.dead_declared = Some((p, now));
        self.nodes[p as usize].counters.inc("fab_dead_declared");
        if let Some(obs) = self.obs.as_mut() {
            let lag = now.since(self.killed.expect("checked above").1);
            obs.flight_record(now, p as u32, FlightKind::DeclareDead, p as u64, lag.ps());
        }
        let ctrl = self.cfg.ol.machine.ctrl_latency;

        // 1. abandoned work
        let abandoned = self.nodes[p as usize].quota - self.nodes[p as usize].completed;
        self.kill_stats.abandoned_ops = abandoned;
        self.target_ops -= abandoned;

        // 2. migrations touching the dead node
        self.kill_stats.dropped_requests += self.mig.drop_parked_from(p);
        let mut saved_parked: Vec<(u8, Message)> = Vec::new();
        for (a, t) in self.mig.moves() {
            let old = self.interleave.home_of(a);
            if old == p {
                // the old home died mid-move: survivors' parked
                // requests re-route to the line's post-death home below
                saved_parked.extend(self.mig.take_parked(a));
                self.mig.end(a);
            } else if t == p {
                // the *target* died: abort at the live old home
                self.abort_migration(old, a);
            }
        }

        // 3. re-interleave
        let rehomed: Vec<LineAddr> = (0..self.region_lines)
            .map(LineAddr)
            .filter(|&a| self.interleave.home_of(a) == p)
            .collect();
        self.interleave.mark_dead(p);
        self.kill_stats.rehomed = rehomed.len() as u64;
        if let Some(obs) = self.obs.as_mut() {
            obs.flight_record(now, p as u32, FlightKind::Rehome, rehomed.len() as u64, p as u64);
        }
        self.granted_to.retain(|_, holder| *holder != p);
        for &a in &rehomed {
            self.mig.forget(a);
            self.granted_to.remove(&a);
        }

        // 4. adoption from cache truth
        for &a in &rehomed {
            let mut holder: Option<(u8, CacheState)> = None;
            for (i, cell) in self.nodes.iter().enumerate() {
                if i == p as usize {
                    continue;
                }
                let st = cell.cache.state_of(a);
                if st == CacheState::I {
                    continue;
                }
                debug_assert!(holder.is_none(), "one talker per line");
                holder = Some((i as u8, st));
            }
            if let Some((holder_node, st)) = holder {
                let view = if st == CacheState::S { RemoteView::S } else { RemoteView::EorM };
                let home = self.interleave.home_of(a);
                self.nodes[home as usize].dcs.adopt_remote(a, view, 1);
                self.granted_to.insert(a, holder_node);
                self.nodes[home as usize].counters.inc("fab_adopted");
            }
        }

        // 5. close the dead node's epochs at surviving homes
        let rehomed_set: HashSet<LineAddr> = rehomed.iter().copied().collect();
        let mut held: Vec<(LineAddr, u32)> = self
            .epochs
            .iter()
            .filter(|((a, holder), _)| *holder == p && !rehomed_set.contains(a))
            .map(|(&(a, _), &k)| (a, k))
            .collect();
        held.sort_unstable_by_key(|(a, _)| a.0);
        for (a, k) in held {
            let home = self.interleave.home_of(a);
            let st = self.nodes[home as usize].dcs.state_of(a);
            let mut remaining = k;
            match st.pending_fwd {
                Some(PendingFwd::ToI) => {
                    // answer the invalidation stalled on the dead
                    // holder; had_copy closes one epoch at the home
                    let rsp = Message::coh_rsp(
                        ReqId(0),
                        Node::Remote,
                        CohOp::FwdDowngradeI,
                        a,
                        false,
                        None,
                    );
                    self.eng.schedule(ctrl, Ev::FabInject(home, Box::new(rsp), p));
                    remaining = remaining.saturating_sub(1);
                }
                Some(PendingFwd::ToS) => {
                    let rsp = Message::coh_rsp(
                        ReqId(0),
                        Node::Remote,
                        CohOp::FwdDowngradeS,
                        a,
                        false,
                        None,
                    );
                    self.eng.schedule(ctrl, Ev::FabInject(home, Box::new(rsp), p));
                }
                // None or AwaitVolDowngrade: the surrenders below are
                // exactly the voluntary downgrades the home awaits
                _ => {}
            }
            for _ in 0..remaining {
                let m = Message::coh_req(ReqId(0), Node::Remote, CohOp::VolDowngradeI, a);
                self.eng.schedule(ctrl, Ev::FabInject(home, Box::new(m), p));
            }
            self.kill_stats.reclaimed += u64::from(k);
            if let Some(obs) = self.obs.as_mut() {
                obs.flight_record(now, home as u32, FlightKind::EpochReclaim, a.0, u64::from(k));
            }
        }
        self.epochs.clear();

        // 6. replay pending forwarded requests (dead-sourced ones drop)
        let (replay, dropped) = self.xlat.on_node_dead(p);
        self.kill_stats.dropped_requests += dropped;
        self.kill_stats.replayed = replay.len() as u64;
        for e in replay {
            let home = self.interleave.home_of(e.msg.addr);
            self.eng.schedule(ctrl, Ev::FabInject(home, Box::new(e.msg), e.src));
        }

        // 7. limboed and saved parked messages follow their new homes
        for (m, src) in std::mem::take(&mut self.limbo) {
            let home = self.interleave.home_of(m.addr);
            self.eng.schedule(ctrl, Ev::FabInject(home, Box::new(m), src));
        }
        for (src, m) in saved_parked {
            let home = self.interleave.home_of(m.addr);
            self.eng.schedule(ctrl, Ev::FabInject(home, Box::new(m), src));
        }

        // post-mortem: snapshot the ring at the declaration instant so
        // the events *leading up to* the failure survive verbatim even
        // if the run continues long enough to overwrite them
        if let Some(fl) = self.obs.as_mut().and_then(|o| o.flight.as_mut()) {
            fl.dump("declare_dead", now);
        }
    }

    // -- reporting ----------------------------------------------------------

    fn report(self) -> FabricReport {
        let sim_time = self.eng.now();
        let mut lat = Histogram::new();
        let mut hop_lat = Histogram::new();
        let mut counters = Counters::new();
        let mut per_node = Vec::with_capacity(self.nodes.len());
        for (i, cell) in self.nodes.into_iter().enumerate() {
            // fabric-wide distributions are the per-node histograms
            // merged — no sample is recorded twice
            lat.merge(&cell.lat);
            hop_lat.merge(&cell.hop_lat);
            let mut nc = cell.dcs.counters();
            for (k, v) in cell.remote.stats.iter() {
                nc.add(k, v);
            }
            for (k, v) in cell.counters.iter() {
                nc.add(k, v);
            }
            nc.add("kvs_lookups", cell.kvs.served);
            let frames_sent = |ing: &FramedIngress| match ing.link.rel.as_ref() {
                Some(r) => r.tx.sent,
                None => ing.link.tx.sent,
            };
            nc.add("frames_to_home", frames_sent(&cell.to_home));
            nc.add("frames_to_cpu", frames_sent(&cell.to_cpu));
            nc.add("home_credit_stalls", cell.to_home.credit_stalls);
            for (k, v) in nc.iter() {
                counters.add(k, v);
            }
            per_node.push(FabricNodeReport {
                node: i,
                completed: cell.completed,
                lat: cell.lat,
                fills_local: nc.get("fab_fills_local"),
                fills_remote: nc.get("fab_fills_remote"),
                migrations_in: nc.get("fab_migrations_in"),
                migrations_out: nc.get("fab_migrations_out"),
                credit_stalls: cell.to_home.credit_stalls,
                counters: nc,
            });
        }
        let delivered_per_s = if sim_time.ps() == 0 {
            0.0
        } else {
            self.completed_total as f64 / sim_time.as_secs()
        };
        let kill = self.cfg.kill.map(|k| KillReport {
            node: k.node,
            killed_at: self.killed.map(|(_, t)| t),
            declared_at: self.dead_declared.map(|(_, t)| t),
            rehomed_lines: self.kill_stats.rehomed,
            replayed: self.kill_stats.replayed,
            reclaimed_epochs: self.kill_stats.reclaimed,
            dropped_requests: self.kill_stats.dropped_requests,
            dropped_responses: self.kill_stats.dropped_responses,
            abandoned_ops: self.kill_stats.abandoned_ops,
            completion_ps: self.completion_ps,
        });
        FabricReport {
            scenario: self.scenario_name,
            nodes: self.cfg.nodes as usize,
            migrate: self.cfg.migrate,
            offered_per_s: self.cfg.ol.rate_per_s * self.cfg.nodes as f64,
            delivered_per_s,
            completed: self.completed_total,
            sim_time,
            lat,
            hop_lat,
            fills_local: counters.get("fab_fills_local"),
            fills_remote: counters.get("fab_fills_remote"),
            migrations: counters.get("fab_migrations_in"),
            moved_lines: self.interleave.moved_lines(),
            events: self.eng.dispatched,
            kill,
            per_node,
            counters,
        }
    }
}

/// Convenience: run `scenario` on a fresh fabric.
pub fn run(cfg: FabricConfig, scenario: &Scenario) -> FabricReport {
    Fabric::new(cfg, scenario).run()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_node_smoke() {
        let sc = Scenario::preset("uniform", 1 << 10, 0.99).expect("preset");
        let cfg = FabricConfig {
            nodes: 2,
            ol: OpenLoopConfig { rate_per_s: 4e6, ops: 800, ..Default::default() },
            ..Default::default()
        };
        let (r, d1) = Fabric::new(cfg, &sc).run_settled();
        assert_eq!(r.completed, 800);
        assert_eq!(r.lat.count(), 800);
        assert_eq!(r.per_node.len(), 2);
        assert!(r.per_node.iter().all(|n| n.completed > 0), "{:?}", r.per_node);
        // the interleave scatters each window across both homes, so
        // roughly half the fills cross the fabric
        assert!(r.fills_remote > 0, "{:?}", r.counters);
        assert!(r.fills_local > 0, "{:?}", r.counters);
        assert!(r.hop_lat.count() > 0, "two-hop fills must cross the fabric");
        assert_eq!(r.migrations, 0, "migration is off");
        // bit-reproducible: same seed, same settled state
        let (r2, d2) = Fabric::new(cfg, &sc).run_settled();
        assert_eq!(d1, d2);
        assert_eq!(r.sim_time, r2.sim_time);
        assert_eq!(r.events, r2.events);
    }

    /// Regression (bugfix): the fault seeds of every directed link in a
    /// fabric must be pairwise distinct. The old affine derivation
    /// (`seed + 2*node(+1)` for node links, `seed + 2*n + 2*c(+1)` for
    /// channels) let links from different families share a seed and
    /// replay correlated fault patterns; the stream_seed scheme packs a
    /// family tag + index + direction into disjoint bits before mixing.
    #[test]
    fn fabric_link_seeds_are_pairwise_distinct_in_a_four_node_fabric() {
        let nodes = 4u64;
        let base = 7u64;
        let mut seen = std::collections::HashSet::new();
        // node<->client links: kind 1, indexed by node, both directions
        for node in 0..nodes {
            for dir in 0..2 {
                assert!(seen.insert(stream_seed(base, 1, node, dir)), "node-link seed collides");
            }
        }
        // inter-node channels: kind 2, indexed by the dense chan index,
        // both directions — exactly the coordinates Fabric::new uses
        for s in 0..nodes {
            for d in 0..nodes {
                if s == d {
                    continue;
                }
                let c = s * nodes + d;
                for dir in 0..2 {
                    assert!(
                        seen.insert(stream_seed(base, 2, c, dir)),
                        "channel seed collides at ({s},{d},{dir})"
                    );
                }
            }
        }
        assert_eq!(seen.len(), (2 * nodes + 2 * nodes * (nodes - 1)) as usize);
    }

    #[test]
    fn killing_a_node_mid_run_completes_survivor_work() {
        let sc = Scenario::preset("uniform", 1 << 9, 0.99).expect("preset");
        let cfg = FabricConfig {
            nodes: 3,
            kill: Some(KillSpec { node: 1, at: Duration::from_us(20) }),
            ol: OpenLoopConfig { rate_per_s: 4e6, ops: 900, ..Default::default() },
            ..Default::default()
        };
        let (r, d1) = Fabric::new(cfg, &sc).run_settled();
        let k = r.kill.as_ref().expect("kill configured");
        assert!(k.killed_at.is_some(), "kill must fire mid-run");
        assert!(k.declared_at.is_some(), "survivors must declare the death");
        assert!(
            k.detect_latency().expect("both stamped").ps() <= cfg.detect.ps(),
            "watchdog bounds detection"
        );
        assert!(k.rehomed_lines > 0, "the dead node homed lines");
        assert_eq!(
            r.completed + k.abandoned_ops,
            900,
            "every non-abandoned op completes: {:?}",
            r.counters
        );
        let dead = &r.per_node[1];
        assert!(dead.completed < 300, "the dead node cannot finish its quota");
        // bit-reproducible under failover too
        let (_, d2) = Fabric::new(cfg, &sc).run_settled();
        assert_eq!(d1, d2);
    }

    #[test]
    fn migration_moves_hot_lines_toward_their_talker() {
        let sc = Scenario::preset("hot-kvs", 1 << 10, 0.99).expect("preset");
        let mk = |migrate: bool| {
            let cfg = FabricConfig {
                nodes: 2,
                migrate,
                threshold: 4,
                ol: OpenLoopConfig { rate_per_s: 4e6, ops: 2_500, ..Default::default() },
                ..Default::default()
            };
            Fabric::new(cfg, &sc).run()
        };
        let off = mk(false);
        let on = mk(true);
        assert_eq!(off.completed, 2_500);
        assert_eq!(on.completed, 2_500, "migration must not lose operations");
        assert!(on.migrations > 0, "hot remote-homed lines must move: {:?}", on.counters);
        assert!(on.moved_lines > 0);
        // every migrated line turns its two-hop fills into local ones
        assert!(
            on.fills_remote < off.fills_remote,
            "migration must cut remote fills: {} vs {}",
            on.fills_remote,
            off.fills_remote
        );
    }

    /// Regression (S2): fabric cells issue in near-lockstep, so
    /// identical sampling phases on every node would trace the *same*
    /// global arrival positions N times over. The derived phases must be
    /// deterministic in the seed and pairwise distinct while distinct
    /// residues mod `every` remain.
    #[test]
    fn span_sampling_phases_are_deterministic_and_pairwise_distinct() {
        for seed in [0u64, 1, 7, 0xdead_beef] {
            let p = span_phases(seed, 4, 8);
            assert_eq!(p, span_phases(seed, 4, 8), "phases must be seed-deterministic");
            assert_eq!(p.len(), 4);
            let set: std::collections::HashSet<u32> = p.iter().copied().collect();
            assert_eq!(set.len(), 4, "phases must be pairwise distinct: {p:?}");
            assert!(p.iter().all(|&x| x < 8));
        }
        // more nodes than residues: the first `every` phases stay
        // distinct, the wrap past that is allowed (and must terminate)
        let p = span_phases(3, 6, 4);
        assert_eq!(p.len(), 6);
        let first: std::collections::HashSet<u32> = p[..4].iter().copied().collect();
        assert_eq!(first.len(), 4);
        // every == 1 degenerates to all-zero (every span sampled anyway)
        assert!(span_phases(9, 3, 1).iter().all(|&x| x == 0));
    }

    /// Acceptance: a 2-node observed run yields a remote-fill span class
    /// whose per-hop + service stage means telescope exactly to the
    /// measured remote end-to-end mean — and the local class likewise.
    #[test]
    fn two_node_remote_spans_telescope_to_their_e2e() {
        let sc = Scenario::preset("uniform", 1 << 10, 0.99).expect("preset");
        let cfg = FabricConfig {
            nodes: 2,
            ol: OpenLoopConfig { rate_per_s: 4e6, ops: 800, ..Default::default() },
            ..Default::default()
        };
        let ocfg = ObsConfig { spans: true, span_sample_every: 1, ..ObsConfig::default() };
        let (r, obs) = Fabric::new(cfg, &sc).with_obs(&ocfg).run_observed();
        assert_eq!(r.completed, 800);
        let w = obs.waterfall.expect("spans were on");
        assert_eq!(w.sampled, 800, "1-in-1 sampling traces every op");
        assert_eq!(w.completed + w.remote_completed, 800, "every span completes");
        assert!(w.remote_completed > 0, "the interleave forces remote fills");
        assert!(w.completed > 0, "and keeps local fills too");
        // telescoping: within each class, stage means sum to e2e mean
        assert!(
            (w.stage_mean_sum_ns() - w.e2e.mean_ns).abs() < 1e-6,
            "local stages must telescope: {} vs {}",
            w.stage_mean_sum_ns(),
            w.e2e.mean_ns
        );
        let er = w.e2e_remote.as_ref().expect("remote fills completed");
        assert!(
            (w.remote_stage_mean_sum_ns() - er.mean_ns).abs() < 1e-6,
            "remote stages must telescope: {} vs {}",
            w.remote_stage_mean_sum_ns(),
            er.mean_ns
        );
        assert_eq!(w.remote_rows.len(), crate::obs::REMOTE_STAGE_NAMES.len());
        // a remote fill pays two extra hops: its mean e2e must exceed local
        assert!(er.mean_ns > w.e2e.mean_ns, "{} vs {}", er.mean_ns, w.e2e.mean_ns);
    }

    /// Acceptance: a kill run with the flight recorder attached emits a
    /// `declare_dead` dump capturing the events leading up to the
    /// declaration, plus the final `end_of_run` snapshot.
    #[test]
    fn kill_run_emits_a_declare_dead_flight_dump() {
        let sc = Scenario::preset("uniform", 1 << 9, 0.99).expect("preset");
        let cfg = FabricConfig {
            nodes: 3,
            kill: Some(KillSpec { node: 1, at: Duration::from_us(20) }),
            ol: OpenLoopConfig { rate_per_s: 4e6, ops: 900, ..Default::default() },
            ..Default::default()
        };
        let ocfg = ObsConfig { flight: Some(64), ..ObsConfig::default() };
        let (r, _digest, obs) = Fabric::new(cfg, &sc).with_obs(&ocfg).run_settled_observed();
        assert!(r.kill.as_ref().and_then(|k| k.declared_at).is_some());
        assert_eq!(obs.flight_dumps.len(), 2, "declare_dead + end_of_run");
        let (trigger, dump) = &obs.flight_dumps[0];
        assert_eq!(trigger, "declare_dead");
        let j = crate::obs::Json::parse(dump).expect("dump must parse as JSON");
        assert_eq!(j.get("trigger").and_then(|t| t.as_str()), Some("declare_dead"));
        let nodes = j.get("nodes").and_then(|n| n.as_arr()).expect("per-node rings");
        let events: u64 = nodes
            .iter()
            .map(|n| n.get("recorded").and_then(|v| v.as_u64()).unwrap_or(0))
            .sum();
        assert!(events > 0, "the ring must hold events at declaration time");
        // the final ring still knows about the kill chronology
        assert!(obs.flight_events.iter().any(|e| matches!(e.kind, FlightKind::DeclareDead)));
        assert_eq!(obs.flight_dumps[1].0, "end_of_run");
    }
}
