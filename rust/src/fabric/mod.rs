//! fabric — the N-node scale-out composition of the two-socket unit
//! cell.
//!
//! Every node is a full open-loop cell (its own sliced directory, FPGA
//! DRAM, KVS pool, streaming/caching client behind real link framing —
//! exactly the [`crate::workload::openloop`] machinery), and the nodes
//! are joined by an inter-node fabric: one framed, credit-managed,
//! optionally reliable link pair per ordered node pair, the same
//! [`FramedIngress`] transport the intra-node links use.
//!
//! Three mechanisms make it a coherence fabric rather than N isolated
//! machines (DESIGN.md §"The fabric subsystem"):
//!
//! * **Global interleave** ([`route::Interleave`]) — every line has
//!   exactly one home node (`addr % nodes`, plus a sparse override
//!   table for migrated lines). A request whose line homes elsewhere is
//!   *forwarded*: the local hop's credit is returned, the message
//!   crosses the fabric link, and the response crosses back — the
//!   two-hop remote-fill path whose cost the `fig_fabric` experiment
//!   measures.
//! * **Id translation** ([`route::IdTranslator`]) — each node's client
//!   numbers its transactions independently, so requests from N clients
//!   meeting at one home directory would collide. The forwarding point
//!   swaps the id for a fabric-unique one (bit 31 set) and the
//!   responding home restores the original, because the source client
//!   matches responses by id.
//! * **Home migration** ([`migrate::Migrator`]) — a line whose traffic
//!   is dominated by one remote node moves its home there.  The move is
//!   a quiesce-and-handoff: new transactions for the line park, in-
//!   flight ones drain (live count reaches zero), the old home flushes
//!   any cached copy and drops its directory entry
//!   ([`crate::dcs::Dcs::surrender_local`]), the backing bytes and the
//!   interleave entry move, and the parked requests are re-injected at
//!   the new home — no request ever observes the line mid-move.  An
//!   `UpgradeS2E` arriving mid-move *aborts* the move instead of
//!   parking: its issuer holds the line in `S`, so the line could never
//!   quiesce while the upgrade waits.
//!
//! Determinism carries over from the unit cell: with one node, the
//! fabric's RNG stream, event sequence, and settled-state digest are
//! bit-identical to a bare [`crate::workload::OpenLoop`] (the
//! `one_node_fabric_equals_openloop` gate in `tests/fabric.rs`).

pub mod migrate;
pub mod route;

pub use migrate::Migrator;
pub use route::{IdTranslator, Interleave};

use std::collections::VecDeque;

use crate::agents::cache::Cache;
use crate::agents::dram::{Dram, MemStore};
use crate::agents::home::HomeEffect;
use crate::agents::remote::{Access, RemoteAgent, RemoteEffect};
use crate::dcs::{Dcs, SliceService};
use crate::memctl::KvsService;
use crate::obs::{Obs, ObsConfig, ObsReport, Registry, Stage};
use crate::proto::messages::{CohOp, LineAddr, Message, MsgKind};
use crate::proto::spec::generate_remote;
use crate::proto::states::Node;
use crate::proto::transitions::reference_transitions;
use crate::rustc_hash::{FxHashMap as HashMap, FxHashSet as HashSet};
use crate::sim::engine::Engine;
use crate::sim::rng::Rng;
use crate::sim::stats::{Counters, Histogram};
use crate::sim::time::{Duration, Time};
use crate::transport::{vc_for, Control, Frame, FramedIngress, VcId};
use crate::workload::openloop::OpenLoopConfig;
use crate::workload::sampler::{SampleKind, TrafficSampler};
use crate::workload::scenario::Scenario;

/// Fabric parameters. The per-node cell (offered rate, client style,
/// link, directory pipeline) comes from the embedded
/// [`OpenLoopConfig`]; `rate_per_s` is *per node* while `ops` is the
/// fabric-wide total (split evenly, remainder to the low nodes).
#[derive(Clone, Copy, Debug)]
pub struct FabricConfig {
    pub nodes: u8,
    /// Enable threshold-based home migration.
    pub migrate: bool,
    /// Response-needing requests from one remote node before its lines
    /// migrate toward it.
    pub threshold: u32,
    /// Directory slices per node.
    pub slices: usize,
    pub ol: OpenLoopConfig,
}

impl Default for FabricConfig {
    fn default() -> FabricConfig {
        FabricConfig {
            nodes: 2,
            migrate: false,
            threshold: 8,
            slices: 2,
            ol: OpenLoopConfig::default(),
        }
    }
}

/// Per-node results.
#[derive(Clone, Debug)]
pub struct FabricNodeReport {
    pub node: usize,
    pub completed: u64,
    /// Arrival-to-completion latency of this node's operations, ps.
    pub lat: Histogram,
    pub fills_local: u64,
    pub fills_remote: u64,
    pub migrations_in: u64,
    pub migrations_out: u64,
    pub credit_stalls: u64,
    pub counters: Counters,
}

/// Results of one fabric run.
#[derive(Debug)]
pub struct FabricReport {
    pub scenario: String,
    pub nodes: usize,
    pub migrate: bool,
    /// Aggregate configured arrival rate (per-node rate x nodes).
    pub offered_per_s: f64,
    /// Aggregate completions over total simulated time.
    pub delivered_per_s: f64,
    pub completed: u64,
    pub sim_time: Time,
    /// Fabric-wide operation latency: the per-node histograms merged
    /// ([`Histogram::merge`]), ps.
    pub lat: Histogram,
    /// Per-frame inter-node hop latency (launch to landing), ps — empty
    /// on a 1-node fabric.
    pub hop_lat: Histogram,
    /// Fills served by the requester's own home slice vs. across the
    /// fabric (two-hop path).
    pub fills_local: u64,
    pub fills_remote: u64,
    /// Committed home migrations.
    pub migrations: u64,
    /// Lines living away from their natural interleave home at the end.
    pub moved_lines: usize,
    /// Simulator events dispatched (host-side cost; the selfperf
    /// metric).
    pub events: u64,
    pub per_node: Vec<FabricNodeReport>,
    pub counters: Counters,
}

impl FabricReport {
    pub fn p50_ns(&self) -> f64 {
        self.lat.p50() as f64 / 1000.0
    }
    pub fn p99_ns(&self) -> f64 {
        self.lat.p99() as f64 / 1000.0
    }
    pub fn p999_ns(&self) -> f64 {
        self.lat.p999() as f64 / 1000.0
    }
    pub fn hop_p99_ns(&self) -> f64 {
        self.hop_lat.p99() as f64 / 1000.0
    }
    /// Remote share of all coherence fills.
    pub fn remote_fill_frac(&self) -> f64 {
        let total = self.fills_local + self.fills_remote;
        if total == 0 {
            0.0
        } else {
            self.fills_remote as f64 / total as f64
        }
    }
}

#[derive(Clone, Copy, Debug)]
enum OpKind {
    Read,
    Write,
    Chase { left: u64 },
}

#[derive(Clone, Copy, Debug)]
struct OpCtx {
    kind: OpKind,
    addr: LineAddr,
    started: Time,
    active: bool,
}

/// Where an admitted directory message came from — decides where its
/// held request-direction credit flows back to when the slice consumes
/// it.
#[derive(Clone, Copy, Debug)]
enum Source {
    /// The home node's own client link.
    Local,
    /// A fabric channel's request direction.
    Chan(u16),
    /// Re-injected after parking (its original credit was returned at
    /// park time).
    Parked,
}

/// What the migration gate decided about an arriving request.
enum Gate {
    Admit,
    Park,
}

/// One node: the full open-loop unit cell, minus the engine (shared)
/// and the fabric-global state.
struct NodeCell {
    dcs: Dcs,
    /// Full global backing image. Only the stripe this node homes is
    /// authoritative; chase pointers (never rewritten) are valid
    /// everywhere.
    mem: MemStore,
    dram: Dram,
    kvs: KvsService,
    remote: RemoteAgent,
    cache: Cache,
    /// Client -> local home slice (requests).
    to_home: FramedIngress,
    /// Local home slice -> client (responses).
    to_cpu: FramedIngress,
    arrivals: Arrivals,
    traffic_rng: Rng,
    sampler: TrafficSampler,
    /// Arrivals this node generates (its share of the fabric total).
    quota: u64,
    ops: Vec<OpCtx>,
    free: Vec<u32>,
    waiters: HashMap<LineAddr, Vec<u32>>,
    chase_ids: HashSet<u32>,
    issued: u64,
    completed: u64,
    poll_at: Vec<Time>,
    peak_in_flight: u32,
    retx_pending: [bool; 2],
    retx_seen_acked: [u64; 2],
    ack_flush_pending: [bool; 2],
    /// Per-(slice, vc) provenance of admitted messages, matched by line
    /// address at service time (see [`Source`]).
    prov: HashMap<(usize, u8), VecDeque<(LineAddr, Source)>>,
    lat: Histogram,
    /// Inter-node hop latency of frames landing at this node.
    hop_lat: Histogram,
    counters: Counters,
}

/// One ordered node pair's fabric link: requests src -> dst, responses
/// dst -> src, each a full framed/credit/rel ingress.
struct FabChan {
    src: u8,
    dst: u8,
    req: FramedIngress,
    rsp: FramedIngress,
    /// Per-direction rel-link timer state (0 = req, 1 = rsp).
    retx_pending: [bool; 2],
    retx_seen_acked: [u64; 2],
    ack_flush_pending: [bool; 2],
}

enum Ev {
    // -- node-local (the open-loop cell, node-tagged) --
    Arrive(u8),
    Step(u8, u32),
    LandHome(u8, Box<Frame>),
    LandCpu(u8, Box<Frame>),
    HomeSend(u8, Box<Message>),
    CtlHome(u8, Control),
    CtlCpu(u8, Control),
    CreditHome(u8, VcId),
    CreditCpu(u8, VcId),
    Poll(u8, u32),
    RetxHome(u8),
    RetxCpu(u8),
    AckFlushHome(u8),
    AckFlushCpu(u8),
    // -- fabric channels (chan-index-tagged) --
    FabLandReq(u16, Box<Frame>),
    FabLandRsp(u16, Box<Frame>),
    /// A home-side response is ready for a channel's return direction.
    FabSendRsp(u16, Box<Message>),
    FabCtlReq(u16, Control),
    FabCtlRsp(u16, Control),
    FabCreditReq(u16, VcId),
    FabCreditRsp(u16, VcId),
    FabRetxReq(u16),
    FabRetxRsp(u16),
    FabAckFlushReq(u16),
    FabAckFlushRsp(u16),
    /// Hand a message (original id restored) from node `2` to home `0`
    /// directly: parked-request re-injection after a migration commits
    /// or aborts, and post-commit races chasing a moved line.
    FabInject(u8, Box<Message>, u8),
}

use crate::workload::arrival::Arrivals;

fn chan_idx(src: u8, dst: u8, nodes: u8) -> u16 {
    debug_assert_ne!(src, dst, "no self-channel");
    src as u16 * nodes as u16 + dst as u16
}

/// Span-tracer keys must be fabric-unique: node in the top bits, the
/// client's transaction id below. With one node this is the identity
/// map, so 1-node fabric waterfalls match open-loop ones exactly.
fn span_key(node: u8, id: u32) -> u32 {
    debug_assert_eq!(id & 0xFC00_0000, 0, "client ids stay below 2^26");
    ((node as u32) << 26) | id
}

/// The N-node fabric host: N open-loop cells on one event engine,
/// joined by framed inter-node channels, a global interleave, and the
/// migration machinery.
pub struct Fabric {
    cfg: FabricConfig,
    scenario_name: String,
    eng: Engine<Ev>,
    nodes: Vec<NodeCell>,
    /// Dense N x N, `None` on the diagonal; index = src * N + dst.
    chans: Vec<Option<FabChan>>,
    interleave: Interleave,
    xlat: IdTranslator,
    mig: Migrator,
    /// Last node granted each line (routes home-initiated `Fwd*` to the
    /// holder).
    granted_to: HashMap<LineAddr, u8>,
    /// Lines per node's traffic window (class windows back to back).
    window_lines: u64,
    /// Total lines across all windows.
    region_lines: u64,
    completed_total: u64,
    scratch: Vec<(Time, Frame)>,
    rx_frames: Vec<Frame>,
    rx_ctls: Vec<Control>,
    obs: Option<Obs>,
}

impl Fabric {
    pub fn new(cfg: FabricConfig, scenario: &Scenario) -> Fabric {
        assert!(cfg.nodes >= 1, "fabric needs at least one node");
        assert!(cfg.slices > 0, "need at least one slice per node");
        assert!(cfg.ol.ops > 0, "need at least one arrival");
        assert!(
            !(cfg.migrate && cfg.ol.cached),
            "home migration requires streaming clients: a caching client \
             never releases its lines, so a mid-move line would never quiesce"
        );
        let n = cfg.nodes as u64;
        let mut master = Rng::new(cfg.ol.seed);
        let spec = reference_transitions();

        let window = scenario.total_lines();
        assert!(window >= 2, "scenario region too small");
        let region = window * n;

        // Pass 1: everything that draws on the master RNG, node-major in
        // the exact open-loop order (shuffle, sampler, links, arrivals,
        // traffic). With one node this is bit-identical to
        // `OpenLoop::new`, which is what the 1-node equivalence gate
        // checks end to end.
        struct Proto {
            chain: Vec<u64>,
            sampler: TrafficSampler,
            to_home: FramedIngress,
            to_cpu: FramedIngress,
            arrivals: Arrivals,
            traffic_rng: Rng,
        }
        let mut protos: Vec<Proto> = Vec::with_capacity(cfg.nodes as usize);
        for node in 0..n {
            let mut chain: Vec<u64> = (0..window).collect();
            master.shuffle(&mut chain);
            let sampler = TrafficSampler::build(scenario, &mut master);
            let to_home = match cfg.ol.machine.rel {
                Some(mut rc) => {
                    rc.faults.seed = rc.faults.seed.wrapping_add(2 * node);
                    FramedIngress::with_rel(cfg.ol.machine.link, Node::Remote, master.fork(2), rc)
                }
                None => FramedIngress::new(cfg.ol.machine.link, Node::Remote, master.fork(2)),
            };
            let to_cpu = match cfg.ol.machine.rel {
                // every link direction draws an independent fault stream
                Some(mut rc) => {
                    rc.faults.seed = rc.faults.seed.wrapping_add(2 * node + 1);
                    FramedIngress::with_rel(cfg.ol.machine.link, Node::Home, master.fork(3), rc)
                }
                None => FramedIngress::new(cfg.ol.machine.link, Node::Home, master.fork(3)),
            };
            let arrivals = Arrivals::new(cfg.ol.arrivals, cfg.ol.rate_per_s, master.fork(4));
            let traffic_rng = master.fork(5);
            protos.push(Proto { chain, sampler, to_home, to_cpu, arrivals, traffic_rng });
        }

        // Fabric channels draw after all nodes (a 1-node fabric builds
        // none, leaving the stream untouched).
        let mut chans: Vec<Option<FabChan>> = Vec::with_capacity((n * n) as usize);
        for s in 0..cfg.nodes {
            for d in 0..cfg.nodes {
                if s == d {
                    chans.push(None);
                    continue;
                }
                let c = s as u64 * n + d as u64;
                let req = match cfg.ol.machine.rel {
                    Some(mut rc) => {
                        rc.faults.seed = rc.faults.seed.wrapping_add(2 * n + 2 * c);
                        FramedIngress::with_rel(
                            cfg.ol.machine.link,
                            Node::Remote,
                            master.fork(1000 + 2 * c),
                            rc,
                        )
                    }
                    None => {
                        FramedIngress::new(cfg.ol.machine.link, Node::Remote, master.fork(1000 + 2 * c))
                    }
                };
                let rsp = match cfg.ol.machine.rel {
                    Some(mut rc) => {
                        rc.faults.seed = rc.faults.seed.wrapping_add(2 * n + 2 * c + 1);
                        FramedIngress::with_rel(
                            cfg.ol.machine.link,
                            Node::Home,
                            master.fork(1000 + 2 * c + 1),
                            rc,
                        )
                    }
                    None => FramedIngress::new(
                        cfg.ol.machine.link,
                        Node::Home,
                        master.fork(1000 + 2 * c + 1),
                    ),
                };
                chans.push(Some(FabChan {
                    src: s,
                    dst: d,
                    req,
                    rsp,
                    retx_pending: [false; 2],
                    retx_seen_acked: [0; 2],
                    ack_flush_pending: [false; 2],
                }));
            }
        }

        // Global backing image: node m's window holds lines
        // [m*window, (m+1)*window); chase chains stay inside their
        // window (pointer = m*window + chain_m[i]).
        let mut image: Vec<[u8; 128]> = Vec::with_capacity(region as usize);
        for (m, p) in protos.iter().enumerate() {
            for i in 0..window {
                let g = m as u64 * window + i;
                let mut line = [0u8; 128];
                line[0..8].copy_from_slice(&g.to_le_bytes());
                line[120..128]
                    .copy_from_slice(&(m as u64 * window + p.chain[i as usize]).to_le_bytes());
                image.push(line);
            }
        }

        let quota_base = cfg.ol.ops / n;
        let quota_rem = cfg.ol.ops % n;
        let mut cells: Vec<NodeCell> = Vec::with_capacity(cfg.nodes as usize);
        for (idx, p) in protos.into_iter().enumerate() {
            let mut mem = MemStore::new(LineAddr(0), (region as usize) * 128);
            for (g, line) in image.iter().enumerate() {
                mem.write_line(LineAddr(g as u64), line);
            }
            let dcs_cfg = if cfg.ol.home_cached {
                cfg.ol.machine.dcs_cached_config(cfg.slices)
            } else {
                cfg.ol.machine.dcs_config(cfg.slices)
            };
            cells.push(NodeCell {
                dcs: Dcs::with_reference_rules(dcs_cfg),
                mem,
                dram: Dram::new(cfg.ol.machine.fpga_dram),
                kvs: KvsService::new(cfg.ol.kvs_engines),
                remote: RemoteAgent::new(Node::Remote, generate_remote(&spec), LineAddr(0), region),
                cache: Cache::new(cfg.ol.machine.cpu.llc_bytes, cfg.ol.machine.cpu.llc_ways),
                to_home: p.to_home,
                to_cpu: p.to_cpu,
                arrivals: p.arrivals,
                traffic_rng: p.traffic_rng,
                sampler: p.sampler,
                quota: quota_base + u64::from((idx as u64) < quota_rem),
                ops: Vec::new(),
                free: Vec::new(),
                waiters: HashMap::default(),
                chase_ids: HashSet::default(),
                issued: 0,
                completed: 0,
                poll_at: vec![Time::ZERO; cfg.slices],
                peak_in_flight: 0,
                retx_pending: [false; 2],
                retx_seen_acked: [0; 2],
                ack_flush_pending: [false; 2],
                prov: HashMap::default(),
                lat: Histogram::new(),
                hop_lat: Histogram::new(),
                counters: Counters::new(),
            });
        }

        Fabric {
            scenario_name: scenario.name.clone(),
            eng: Engine::new(),
            nodes: cells,
            chans,
            interleave: Interleave::new(cfg.nodes),
            xlat: IdTranslator::new(),
            mig: Migrator::new(),
            granted_to: HashMap::default(),
            window_lines: window,
            region_lines: region,
            completed_total: 0,
            scratch: Vec::new(),
            rx_frames: Vec::new(),
            rx_ctls: Vec::new(),
            obs: None,
            cfg,
        }
    }

    /// Attach passive observability before running (span tracing and/or
    /// the telemetry ticker); collect through [`Fabric::run_observed`]
    /// or [`Fabric::run_settled_observed`].
    pub fn with_obs(mut self, ocfg: &ObsConfig) -> Fabric {
        if ocfg.enabled() {
            self.obs = Some(Obs::new(ocfg));
        }
        self
    }

    /// Run until every arrival on every node has completed.
    pub fn run(mut self) -> FabricReport {
        self.run_to_completion();
        self.report()
    }

    /// Run to completion, settle every trailing event (releases,
    /// replays, credit returns, parked re-injections), and digest the
    /// final global state: for every line, the *home* node's directory
    /// state and backing bytes. On one node this digest is computed
    /// exactly as [`crate::workload::OpenLoop::run_settled`] computes
    /// its own.
    pub fn run_settled(mut self) -> (FabricReport, u64) {
        let digest = self.settle();
        (self.report(), digest)
    }

    pub fn run_observed(mut self) -> (FabricReport, ObsReport) {
        self.run_to_completion();
        let obs = self.finish_obs();
        (self.report(), obs)
    }

    pub fn run_settled_observed(mut self) -> (FabricReport, u64, ObsReport) {
        let digest = self.settle();
        let obs = self.finish_obs();
        (self.report(), digest, obs)
    }

    fn settle(&mut self) -> u64 {
        self.run_to_completion();
        while let Some((_, ev)) = self.eng.pop() {
            self.dispatch(ev);
            self.obs_tick();
        }
        debug_assert_eq!(self.mig.in_flight(), 0, "settled with a migration mid-move");
        debug_assert_eq!(self.xlat.pending(), 0, "settled with unresolved forwarded ids");
        self.state_digest()
    }

    fn run_to_completion(&mut self) {
        for node in 0..self.cfg.nodes {
            if self.nodes[node as usize].quota > 0 {
                self.eng.schedule(Duration::ZERO, Ev::Arrive(node));
            }
        }
        while self.completed_total < self.cfg.ol.ops {
            let Some((_, ev)) = self.eng.pop() else {
                let per: Vec<(u64, u64, usize)> = self
                    .nodes
                    .iter()
                    .map(|c| (c.completed, c.quota, c.dcs.pending()))
                    .collect();
                panic!(
                    "fabric deadlock: {} of {} ops complete, {} moves in flight, \
                     per-node (completed, quota, dcs-pending) {:?}",
                    self.completed_total,
                    self.cfg.ol.ops,
                    self.mig.in_flight(),
                    per
                );
            };
            self.dispatch(ev);
            self.obs_tick();
        }
    }

    fn obs_tick(&mut self) {
        let now = self.eng.now();
        if !self.obs.as_ref().is_some_and(|o| o.tick_due(now)) {
            return;
        }
        let mut obs = self.obs.take().expect("checked above");
        self.refresh_registry(&mut obs.registry);
        if let Some(sp) = &obs.spans {
            obs.registry.gauge("obs.live_spans", sp.live_spans() as f64);
        }
        obs.tick(now);
        self.obs = Some(obs);
    }

    /// Absorb every node's counter surfaces under `node<N>.`-prefixed
    /// dotted names (no collisions across nodes), plus the fabric
    /// channels and the merged rel-link stats.
    fn refresh_registry(&self, reg: &mut Registry) {
        let mut rel = None;
        let mut eat_rel = |ing: &FramedIngress, rel: &mut Option<crate::transport::rel::RelStats>| {
            if let Some(s) = ing.rel_stats() {
                match rel {
                    Some(acc) => acc.merge(&s),
                    None => *rel = Some(s),
                }
            }
        };
        for (i, cell) in self.nodes.iter().enumerate() {
            reg.absorb(&format!("node{i}.workload"), &cell.counters);
            reg.set(&format!("node{i}.workload.issued"), cell.issued);
            reg.set(&format!("node{i}.workload.completed"), cell.completed);
            reg.set(&format!("node{i}.workload.kvs_lookups"), cell.kvs.served);
            reg.absorb(&format!("node{i}.dcs"), &cell.dcs.counters());
            cell.dcs.observe_gauges(&format!("node{i}.dcs"), reg);
            cell.to_home.observe(&format!("node{i}.ingress.to_home"), reg);
            cell.to_cpu.observe(&format!("node{i}.ingress.to_cpu"), reg);
            eat_rel(&cell.to_home, &mut rel);
            eat_rel(&cell.to_cpu, &mut rel);
        }
        for ch in self.chans.iter().flatten() {
            let (s, d) = (ch.src, ch.dst);
            ch.req.observe(&format!("node{s}.flink{d}.req"), reg);
            ch.rsp.observe(&format!("node{s}.flink{d}.rsp"), reg);
            eat_rel(&ch.req, &mut rel);
            eat_rel(&ch.rsp, &mut rel);
        }
        reg.set("fabric.moved_lines", self.interleave.moved_lines() as u64);
        reg.set("fabric.migrations_in_flight", self.mig.in_flight() as u64);
        reg.set("fabric.ids_pending", self.xlat.pending() as u64);
        if let Some(s) = rel {
            reg.absorb_rel("rel", &s);
        }
    }

    fn finish_obs(&mut self) -> ObsReport {
        let mut obs = self.obs.take().expect("attach obs with with_obs first");
        self.refresh_registry(&mut obs.registry);
        obs.tick(self.eng.now());
        obs.finish()
    }

    /// FNV-1a over every line's directory state *at its home node* and
    /// that node's backing bytes.
    fn state_digest(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat = |h: &mut u64, b: u8| {
            *h ^= b as u64;
            *h = h.wrapping_mul(FNV_PRIME);
        };
        for i in 0..self.region_lines {
            let addr = LineAddr(i);
            let home = self.interleave.home_of(addr) as usize;
            for b in format!("{:?}", self.nodes[home].dcs.state_of(addr)).bytes() {
                eat(&mut h, b);
            }
            for &b in self.nodes[home].mem.read_line(addr).iter() {
                eat(&mut h, b);
            }
        }
        h
    }

    fn dispatch(&mut self, ev: Ev) {
        match ev {
            Ev::Arrive(n) => self.arrive(n),
            Ev::Step(n, s) => self.step(n, s),
            Ev::LandHome(n, f) => self.land_home(n, f),
            Ev::LandCpu(n, f) => self.land_cpu(n, f),
            Ev::HomeSend(n, m) => {
                self.nodes[n as usize].to_cpu.offer(*m);
                self.pump_cpu(n);
            }
            Ev::CtlHome(n, c) => {
                let now = self.eng.now();
                self.nodes[n as usize].to_home.on_control(now, c);
                self.pump_home(n);
            }
            Ev::CtlCpu(n, c) => {
                let now = self.eng.now();
                self.nodes[n as usize].to_cpu.on_control(now, c);
                self.pump_cpu(n);
            }
            Ev::CreditHome(n, vc) => {
                self.nodes[n as usize].to_home.credit_return(vc);
                self.pump_home(n);
            }
            Ev::CreditCpu(n, vc) => {
                self.nodes[n as usize].to_cpu.credit_return(vc);
                self.pump_cpu(n);
            }
            Ev::Poll(n, s) => self.pump_slice(n, s as usize),
            Ev::RetxHome(n) => self.on_retx(n, 0),
            Ev::RetxCpu(n) => self.on_retx(n, 1),
            Ev::AckFlushHome(n) => self.on_ack_flush(n, 0),
            Ev::AckFlushCpu(n) => self.on_ack_flush(n, 1),
            Ev::FabLandReq(c, f) => self.fab_land_req(c, f),
            Ev::FabLandRsp(c, f) => self.fab_land_rsp(c, f),
            Ev::FabSendRsp(c, m) => {
                self.chans[c as usize].as_mut().expect("off-diagonal").rsp.offer(*m);
                self.pump_chan(c, 1);
            }
            Ev::FabCtlReq(c, ctl) => {
                let now = self.eng.now();
                self.chans[c as usize].as_mut().expect("off-diagonal").req.on_control(now, ctl);
                self.pump_chan(c, 0);
            }
            Ev::FabCtlRsp(c, ctl) => {
                let now = self.eng.now();
                self.chans[c as usize].as_mut().expect("off-diagonal").rsp.on_control(now, ctl);
                self.pump_chan(c, 1);
            }
            Ev::FabCreditReq(c, vc) => {
                self.chans[c as usize].as_mut().expect("off-diagonal").req.credit_return(vc);
                self.pump_chan(c, 0);
            }
            Ev::FabCreditRsp(c, vc) => {
                self.chans[c as usize].as_mut().expect("off-diagonal").rsp.credit_return(vc);
                self.pump_chan(c, 1);
            }
            Ev::FabRetxReq(c) => self.on_chan_retx(c, 0),
            Ev::FabRetxRsp(c) => self.on_chan_retx(c, 1),
            Ev::FabAckFlushReq(c) => self.on_chan_ack_flush(c, 0),
            Ev::FabAckFlushRsp(c) => self.on_chan_ack_flush(c, 1),
            Ev::FabInject(h, m, src) => self.fab_inject(h, *m, src),
        }
    }

    // -- arrivals -----------------------------------------------------------

    fn arrive(&mut self, n: u8) {
        if self.nodes[n as usize].issued >= self.nodes[n as usize].quota {
            return;
        }
        self.spawn(n);
        let cell = &mut self.nodes[n as usize];
        if cell.issued < cell.quota {
            let gap = cell.arrivals.next_gap();
            self.eng.schedule(gap, Ev::Arrive(n));
        }
    }

    fn spawn(&mut self, n: u8) {
        let now = self.eng.now();
        let base = n as u64 * self.window_lines;
        let cell = &mut self.nodes[n as usize];
        let (_, kind, line) = cell.sampler.sample(&mut cell.traffic_rng);
        let kind = match kind {
            SampleKind::Read => OpKind::Read,
            SampleKind::Write => OpKind::Write,
            SampleKind::Chase { hops } => OpKind::Chase { left: hops },
        };
        // each node draws inside its own window: windows are disjoint,
        // so every line has exactly one *talker* — but its home is
        // wherever the interleave puts it
        let ctx = OpCtx { kind, addr: LineAddr(base + line), started: now, active: true };
        let slot = match cell.free.pop() {
            Some(s) => {
                cell.ops[s as usize] = ctx;
                s
            }
            None => {
                cell.ops.push(ctx);
                (cell.ops.len() - 1) as u32
            }
        };
        cell.issued += 1;
        self.step(n, slot);
    }

    // -- client side --------------------------------------------------------

    /// Single admission point for node `n`'s client traffic toward its
    /// local home hop (span stage `Issue`).
    fn offer_home(&mut self, n: u8, m: Message) {
        if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
            if let MsgKind::CohReq { op } = &m.kind {
                if op.needs_response() {
                    sp.on_issue(self.eng.now(), span_key(n, m.id.0));
                }
            }
        }
        self.nodes[n as usize].to_home.offer(m);
    }

    fn step(&mut self, n: u8, slot: u32) {
        let (addr, write, is_chase) = {
            let o = &self.nodes[n as usize].ops[slot as usize];
            debug_assert!(o.active, "step on a completed op slot");
            (o.addr, matches!(o.kind, OpKind::Write), matches!(o.kind, OpKind::Chase { .. }))
        };
        let (acc, fx) = {
            let cell = &mut self.nodes[n as usize];
            cell.remote.local_access(addr, write, &mut cell.cache)
        };
        let mut sent = false;
        for e in fx {
            match e {
                RemoteEffect::Send(m) => {
                    if is_chase {
                        if let MsgKind::CohReq { op } = &m.kind {
                            if op.needs_response() {
                                self.nodes[n as usize].chase_ids.insert(m.id.0);
                            }
                        }
                    }
                    self.offer_home(n, m);
                    sent = true;
                }
                RemoteEffect::Stalled => {}
                RemoteEffect::Filled { .. } => {}
                RemoteEffect::ForeignVictim(_) => {
                    self.nodes[n as usize].counters.inc("foreign_victim")
                }
            }
        }
        if sent {
            self.pump_home(n);
        }
        match acc {
            Access::Hit => self.access_done(n, slot),
            Access::Pending => {
                let cell = &mut self.nodes[n as usize];
                cell.waiters.entry(addr).or_default().push(slot);
                if !sent {
                    cell.counters.inc("mshr_merged");
                }
            }
        }
    }

    fn access_done(&mut self, n: u8, slot: u32) {
        let now = self.eng.now();
        let (kind, addr) = {
            let o = &self.nodes[n as usize].ops[slot as usize];
            (o.kind, o.addr)
        };
        match kind {
            OpKind::Write => {
                if let Some(e) = self.nodes[n as usize].cache.lookup(addr) {
                    e.data[0..8].copy_from_slice(&now.ps().to_le_bytes());
                }
                self.finish(n, slot, addr);
            }
            OpKind::Read => self.finish(n, slot, addr),
            OpKind::Chase { left } => {
                if left <= 1 {
                    self.finish(n, slot, addr);
                    return;
                }
                let data = {
                    let cell = &mut self.nodes[n as usize];
                    // chase pointers (bytes 120..128) are never
                    // rewritten, so even a node's stale copy of a
                    // remote-homed line decodes the right next hop
                    cell.cache
                        .peek(addr)
                        .map(|e| *e.data)
                        .unwrap_or_else(|| cell.mem.read_line(addr))
                };
                let ptr = u64::from_le_bytes(data[120..128].try_into().unwrap());
                if !self.cfg.ol.cached {
                    self.release(n, addr);
                }
                let o = &mut self.nodes[n as usize].ops[slot as usize];
                o.addr = LineAddr(ptr % self.region_lines);
                o.kind = OpKind::Chase { left: left - 1 };
                self.eng.schedule(self.cfg.ol.hop_think, Ev::Step(n, slot));
            }
        }
    }

    fn finish(&mut self, n: u8, slot: u32, addr: LineAddr) {
        let now = self.eng.now();
        {
            let cell = &mut self.nodes[n as usize];
            let started = cell.ops[slot as usize].started;
            cell.lat.record(now.since(started).ps());
            cell.ops[slot as usize].active = false;
            cell.completed += 1;
            cell.free.push(slot);
        }
        self.completed_total += 1;
        if !self.cfg.ol.cached {
            self.release(n, addr);
        }
    }

    fn release(&mut self, n: u8, addr: LineAddr) {
        let fx = {
            let cell = &mut self.nodes[n as usize];
            cell.remote.evict(addr, &mut cell.cache)
        };
        let mut sent = false;
        for e in fx {
            match e {
                RemoteEffect::Send(m) => {
                    self.offer_home(n, m);
                    sent = true;
                }
                RemoteEffect::Stalled => self.nodes[n as usize].counters.inc("release_deferred"),
                RemoteEffect::Filled { .. } => {}
                RemoteEffect::ForeignVictim(_) => {
                    self.nodes[n as usize].counters.inc("foreign_victim")
                }
            }
        }
        if sent {
            self.nodes[n as usize].counters.inc("released");
            self.pump_home(n);
        }
    }

    fn wake(&mut self, n: u8, addr: LineAddr) {
        let Some(slots) = self.nodes[n as usize].waiters.remove(&addr) else { return };
        for s in slots {
            self.eng.schedule(Duration::ZERO, Ev::Step(n, s));
        }
    }

    // -- node-local link pumping -------------------------------------------

    fn pump_home(&mut self, n: u8) {
        let now = self.eng.now();
        let mut out = std::mem::take(&mut self.scratch);
        {
            let cell = &mut self.nodes[n as usize];
            cell.to_home.steal_piggy_from(&mut cell.to_cpu);
            cell.to_home.pump(now, &mut out);
        }
        for (at, f) in out.drain(..) {
            if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                sp.mark(now, span_key(n, f.msg.id.0), Stage::Launch);
            }
            self.eng.schedule_at(at, Ev::LandHome(n, Box::new(f)));
        }
        self.scratch = out;
        let cell = &mut self.nodes[n as usize];
        cell.peak_in_flight = cell.peak_in_flight.max(cell.to_home.in_flight_total());
        self.arm_retx(n, 0);
    }

    fn pump_cpu(&mut self, n: u8) {
        let now = self.eng.now();
        let mut out = std::mem::take(&mut self.scratch);
        {
            let cell = &mut self.nodes[n as usize];
            cell.to_cpu.steal_piggy_from(&mut cell.to_home);
            cell.to_cpu.pump(now, &mut out);
        }
        for (at, f) in out.drain(..) {
            self.eng.schedule_at(at, Ev::LandCpu(n, Box::new(f)));
        }
        self.scratch = out;
        self.arm_retx(n, 1);
    }

    fn on_retx(&mut self, n: u8, dir: usize) {
        let cell = &mut self.nodes[n as usize];
        cell.retx_pending[dir] = false;
        let ing = if dir == 0 { &mut cell.to_home } else { &mut cell.to_cpu };
        if ing.rel_unacked() == 0 {
            return;
        }
        if ing.rel_acked() == cell.retx_seen_acked[dir] {
            ing.rel_force_replay();
        }
        if dir == 0 {
            self.pump_home(n);
        } else {
            self.pump_cpu(n);
        }
    }

    fn arm_retx(&mut self, n: u8, dir: usize) {
        let cell = &mut self.nodes[n as usize];
        let ing = if dir == 0 { &cell.to_home } else { &cell.to_cpu };
        let Some(rto) = ing.link.rel_rto() else { return };
        if ing.rel_unacked() == 0 || cell.retx_pending[dir] {
            return;
        }
        cell.retx_seen_acked[dir] = ing.rel_acked();
        cell.retx_pending[dir] = true;
        self.eng.schedule(rto, if dir == 0 { Ev::RetxHome(n) } else { Ev::RetxCpu(n) });
    }

    fn on_ack_flush(&mut self, n: u8, dir: usize) {
        self.nodes[n as usize].ack_flush_pending[dir] = false;
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        loop {
            let cell = &mut self.nodes[n as usize];
            let ing = if dir == 0 { &mut cell.to_home } else { &mut cell.to_cpu };
            let Some((vc, seq)) = ing.take_piggy_ack() else { break };
            let ctl = Control::VcAck(vc, seq);
            self.eng
                .schedule(ctrl, if dir == 0 { Ev::CtlHome(n, ctl) } else { Ev::CtlCpu(n, ctl) });
        }
    }

    fn arm_ack_flush(&mut self, n: u8, dir: usize) {
        let cell = &mut self.nodes[n as usize];
        let ing = if dir == 0 { &cell.to_home } else { &cell.to_cpu };
        if cell.ack_flush_pending[dir] || !ing.rel_has_ack_debt() {
            return;
        }
        cell.ack_flush_pending[dir] = true;
        self.eng.schedule(
            crate::transport::rel::ACK_FLUSH_DELAY,
            if dir == 0 { Ev::AckFlushHome(n) } else { Ev::AckFlushCpu(n) },
        );
    }

    // -- routing & admission ------------------------------------------------

    /// A frame from node `n`'s client lands at node `n`'s home hop:
    /// admit it locally if the line homes here, else forward it across
    /// the fabric.
    fn land_home(&mut self, n: u8, frame: Box<Frame>) {
        let now = self.eng.now();
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        let mut delivered = std::mem::take(&mut self.rx_frames);
        let mut ctls = std::mem::take(&mut self.rx_ctls);
        {
            let cell = &mut self.nodes[n as usize];
            if let Some((vc, seq)) = frame.ack {
                cell.to_cpu.on_control(now, Control::VcAck(vc, seq));
            }
            cell.to_home.deliver(*frame, &mut delivered, &mut ctls);
        }
        for c in ctls.drain(..) {
            self.eng.schedule(ctrl, Ev::CtlHome(n, c));
        }
        self.rx_ctls = ctls;
        self.arm_ack_flush(n, 0);
        for f in delivered.drain(..) {
            self.route_local(n, f);
        }
        self.rx_frames = delivered;
    }

    fn route_local(&mut self, n: u8, mut f: Frame) {
        let home = self.interleave.home_of(f.msg.addr);
        if home == n {
            self.admit_frame(n, n, f, Source::Local);
            return;
        }
        // Two-hop path. The local hop is done with this frame: return
        // its credit, translate the id of anything that expects a
        // response (per-node id spaces collide at the remote home), and
        // put the message on the fabric channel.
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        self.eng.schedule(ctrl, Ev::CreditHome(n, f.vc));
        if let MsgKind::CohReq { op } = &f.msg.kind {
            if op.needs_response() && op.initiator() == Node::Remote {
                f.msg.id = self.xlat.translate(n, f.msg.id);
            }
        }
        self.nodes[n as usize].counters.inc("fab_fwd_out");
        let c = chan_idx(n, home, self.cfg.nodes);
        self.chans[c as usize].as_mut().expect("off-diagonal").req.offer(f.msg);
        self.pump_chan(c, 0);
    }

    /// The migration gate, run on every client-initiated
    /// response-needing request reaching home `h` from node `src`.
    /// Everything else (voluntary downgrades, fwd responses) always
    /// admits — those are the messages a quiescing line is waiting for.
    fn migration_gate(&mut self, h: u8, src: u8, msg: &Message) -> Gate {
        if !self.cfg.migrate {
            return Gate::Admit;
        }
        let addr = msg.addr;
        let MsgKind::CohReq { op } = msg.kind else { return Gate::Admit };
        if !op.needs_response() || op.initiator() != Node::Remote {
            return Gate::Admit;
        }
        if self.mig.target_of(addr).is_some() {
            if matches!(op, CohOp::UpgradeS2E) {
                // the issuer holds the line in S — it can never quiesce
                // while this waits, so the move loses
                self.abort_migration(h, addr);
                // fall through to fresh accounting below
            } else {
                return Gate::Park;
            }
        }
        if self.mig.note(addr, src, h, self.cfg.threshold) {
            self.mig.begin(addr, src);
            self.nodes[h as usize].counters.inc("fab_migration_begin");
            // the trigger request parks too: it completes at the new home
            return Gate::Park;
        }
        Gate::Admit
    }

    /// Admit a delivered frame into home `h`'s directory (or park it if
    /// the line is mid-move). `src` is the requesting node; `source`
    /// says which transport hop holds the credit.
    fn admit_frame(&mut self, h: u8, src: u8, f: Frame, source: Source) {
        let now = self.eng.now();
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        match self.migration_gate(h, src, &f.msg) {
            Gate::Park => {
                let vc = f.vc;
                let mut msg = f.msg;
                // restore the original id before parking: re-injection
                // happens node-to-node, past the translation point
                let true_src = if IdTranslator::is_translated(msg.id) {
                    let (s0, orig) = self.xlat.resolve(msg.id).expect("translated id pending");
                    msg.id = orig;
                    s0
                } else {
                    src
                };
                let addr = msg.addr;
                self.mig.park(addr, true_src, msg);
                self.nodes[h as usize].counters.inc("fab_parked");
                // the message left the wire: release the hop's credit
                match source {
                    Source::Local => self.eng.schedule(ctrl, Ev::CreditHome(h, vc)),
                    Source::Chan(c) => self.eng.schedule(ctrl, Ev::FabCreditReq(c, vc)),
                    Source::Parked => {}
                }
                self.try_commit(h, addr);
            }
            Gate::Admit => {
                if self.cfg.migrate {
                    self.mig.live_inc(f.msg.addr);
                }
                if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                    let key = match self.xlat.peek(f.msg.id) {
                        Some((s0, orig)) => span_key(s0, orig.0),
                        None => span_key(src, f.msg.id.0),
                    };
                    sp.mark(now, key, Stage::Deliver);
                }
                let addr = f.msg.addr;
                let vc = f.vc;
                let cell = &mut self.nodes[h as usize];
                let s = cell.dcs.enqueue_frame(now, f);
                cell.prov.entry((s, vc.0)).or_default().push_back((addr, source));
                self.pump_slice(h, s);
            }
        }
    }

    /// Direct message injection at home `h` (parked re-injection and
    /// post-commit races). The id is already the original; the credit
    /// was returned when the message first left its wire.
    fn fab_inject(&mut self, h: u8, msg: Message, src: u8) {
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        let addr = msg.addr;
        let home = self.interleave.home_of(addr);
        if home != h {
            // the line moved again while this was in flight: chase it
            self.nodes[h as usize].counters.inc("fab_late_reforward");
            self.eng.schedule(ctrl, Ev::FabInject(home, Box::new(msg), src));
            return;
        }
        match self.migration_gate(h, src, &msg) {
            Gate::Park => {
                self.mig.park(addr, src, msg);
                self.nodes[h as usize].counters.inc("fab_parked");
                self.try_commit(h, addr);
            }
            Gate::Admit => {
                let now = self.eng.now();
                if self.cfg.migrate {
                    self.mig.live_inc(addr);
                }
                if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                    sp.mark(now, span_key(src, msg.id.0), Stage::Deliver);
                }
                let vc = vc_for(&msg);
                let cell = &mut self.nodes[h as usize];
                let s = cell.dcs.slice_of(addr);
                cell.dcs.enqueue(now, msg);
                cell.prov.entry((s, vc.0)).or_default().push_back((addr, Source::Parked));
                self.pump_slice(h, s);
            }
        }
    }

    // -- home migration -----------------------------------------------------

    /// Commit the move of `addr` away from `h` if the line has fully
    /// quiesced: nothing admitted and un-serviced (live count zero) and
    /// the old home able to surrender — no remote possession, no
    /// pending forward, no stalled events, any dirty home-cache copy
    /// flushed. Called after every park and every serviced message for
    /// the line, so the commit happens at the first quiet instant.
    fn try_commit(&mut self, h: u8, addr: LineAddr) {
        let Some(target) = self.mig.target_of(addr) else { return };
        if self.mig.live(addr) != 0 {
            return;
        }
        let surrendered = {
            let cell = &mut self.nodes[h as usize];
            let (dcs, mem) = (&mut cell.dcs, &mut cell.mem);
            dcs.surrender_local(addr, mem)
        };
        if !surrendered {
            return;
        }
        // handoff: the old home's backing bytes are now authoritative —
        // move them, flip the interleave, re-home the parked requests
        let line = self.nodes[h as usize].mem.read_line(addr);
        self.nodes[target as usize].mem.write_line(addr, &line);
        self.interleave.set_home(addr, target);
        self.granted_to.remove(&addr);
        self.nodes[h as usize].counters.inc("fab_migrations_out");
        self.nodes[target as usize].counters.inc("fab_migrations_in");
        let parked = self.mig.take_parked(addr);
        self.mig.end(addr);
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        for (src, m) in parked {
            self.eng.schedule(ctrl, Ev::FabInject(target, Box::new(m), src));
        }
    }

    /// Abort the move of `addr` (an `UpgradeS2E` arrived; see
    /// [`Fabric::migration_gate`]): re-inject everything parked at the
    /// *current* home and drop the move state.
    fn abort_migration(&mut self, h: u8, addr: LineAddr) {
        let parked = self.mig.take_parked(addr);
        self.mig.end(addr);
        self.nodes[h as usize].counters.inc("fab_migration_abort");
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        for (src, m) in parked {
            self.eng.schedule(ctrl, Ev::FabInject(h, Box::new(m), src));
        }
    }

    // -- directory service --------------------------------------------------

    fn pump_slice(&mut self, h: u8, s: usize) {
        let now = self.eng.now();
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        loop {
            let res = {
                let cell = &mut self.nodes[h as usize];
                let (dcs, mem) = (&mut cell.dcs, &mut cell.mem);
                dcs.service_one(s, now, mem)
            };
            match res {
                None => break,
                Some(SliceService::Busy(t)) => {
                    let cell = &mut self.nodes[h as usize];
                    if cell.poll_at[s] < t {
                        cell.poll_at[s] = t;
                        self.eng.schedule_at(t, Ev::Poll(h, s as u32));
                    }
                    break;
                }
                Some(SliceService::Done(ready, vc, addr, fx)) => {
                    let source = {
                        let cell = &mut self.nodes[h as usize];
                        let q = cell
                            .prov
                            .get_mut(&(s, vc.0))
                            .expect("every serviced message was admitted");
                        let i = q
                            .iter()
                            .position(|(a, _)| *a == addr)
                            .expect("provenance recorded at admission");
                        q.remove(i).expect("index from position").1
                    };
                    match source {
                        Source::Local => {
                            self.eng.schedule_at(ready + ctrl, Ev::CreditHome(h, vc))
                        }
                        Source::Chan(c) => {
                            self.eng.schedule_at(ready + ctrl, Ev::FabCreditReq(c, vc))
                        }
                        Source::Parked => {}
                    }
                    if self.cfg.migrate {
                        self.mig.live_dec(addr);
                    }
                    self.handle_effects(h, ready, fx);
                    if self.cfg.migrate {
                        self.try_commit(h, addr);
                    }
                }
            }
        }
    }

    fn handle_effects(&mut self, h: u8, ready: Time, fx: Vec<HomeEffect>) {
        let nodes = self.cfg.nodes;
        for e in fx {
            match e {
                HomeEffect::Respond { mut msg, from_ram } => {
                    // restore the requester's id and learn who it was
                    let (src, orig) = if IdTranslator::is_translated(msg.id) {
                        self.xlat.resolve(msg.id).expect("translated id pending")
                    } else {
                        (h, msg.id)
                    };
                    let is_chase = self.nodes[src as usize].chase_ids.remove(&orig.0);
                    let addr = msg.addr;
                    let t = {
                        let cell = &mut self.nodes[h as usize];
                        if is_chase {
                            cell.counters.inc("chase_via_kvs");
                            cell.kvs.submit(ready, 1, &mut cell.dram)
                        } else if from_ram {
                            cell.dram.read(ready, addr)
                        } else {
                            ready
                        }
                    };
                    if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                        let proc = self.nodes[h as usize].dcs.cfg.slice_proc.ps();
                        let key = span_key(src, orig.0);
                        sp.mark(Time(ready.ps().saturating_sub(proc)), key, Stage::SvcStart);
                        sp.mark(ready, key, Stage::SvcDone);
                        sp.mark(t, key, Stage::Reply);
                    }
                    msg.id = orig;
                    self.granted_to.insert(addr, src);
                    self.nodes[h as usize]
                        .counters
                        .inc(if src == h { "fab_fills_local" } else { "fab_fills_remote" });
                    if src == h {
                        self.eng.schedule_at(t, Ev::HomeSend(h, Box::new(msg)));
                    } else {
                        self.eng
                            .schedule_at(t, Ev::FabSendRsp(chan_idx(src, h, nodes), Box::new(msg)));
                    }
                }
                HomeEffect::Fwd { msg } => {
                    // home-initiated downgrade: route to the last holder
                    let dst = self.granted_to.get(&msg.addr).copied().unwrap_or(h);
                    self.nodes[h as usize].counters.inc("fab_fwds");
                    if dst == h {
                        self.eng.schedule_at(ready, Ev::HomeSend(h, Box::new(msg)));
                    } else {
                        self.eng.schedule_at(
                            ready,
                            Ev::FabSendRsp(chan_idx(dst, h, nodes), Box::new(msg)),
                        );
                    }
                }
                HomeEffect::RamWrite { addr } => {
                    self.nodes[h as usize].dram.write(ready, addr);
                }
                HomeEffect::LocalDone { .. } => {}
            }
        }
    }

    // -- node-local response landing ----------------------------------------

    fn land_cpu(&mut self, n: u8, frame: Box<Frame>) {
        let now = self.eng.now();
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        let mut delivered = std::mem::take(&mut self.rx_frames);
        let mut ctls = std::mem::take(&mut self.rx_ctls);
        {
            let cell = &mut self.nodes[n as usize];
            if let Some((avc, seq)) = frame.ack {
                cell.to_home.on_control(now, Control::VcAck(avc, seq));
            }
            cell.to_cpu.deliver(*frame, &mut delivered, &mut ctls);
        }
        for c in ctls.drain(..) {
            self.eng.schedule(ctrl, Ev::CtlCpu(n, c));
        }
        self.rx_ctls = ctls;
        self.arm_ack_flush(n, 1);
        let mut sent = false;
        let mut fills: Vec<LineAddr> = Vec::new();
        for f in delivered.drain(..) {
            self.eng.schedule(ctrl, Ev::CreditCpu(n, f.vc));
            if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                if matches!(f.msg.kind, MsgKind::CohRsp { .. }) {
                    sp.complete(now, span_key(n, f.msg.id.0));
                }
            }
            let fx = {
                let cell = &mut self.nodes[n as usize];
                cell.remote.on_message(f.msg, &mut cell.cache)
            };
            for e in fx {
                match e {
                    RemoteEffect::Send(m) => {
                        self.offer_home(n, m);
                        sent = true;
                    }
                    RemoteEffect::Filled { addr } => fills.push(addr),
                    RemoteEffect::Stalled => {}
                    RemoteEffect::ForeignVictim(_) => {
                        self.nodes[n as usize].counters.inc("foreign_victim")
                    }
                }
            }
        }
        self.rx_frames = delivered;
        if sent {
            self.pump_home(n);
        }
        for a in fills {
            self.wake(n, a);
        }
    }

    // -- fabric channel pumping ---------------------------------------------

    fn pump_chan(&mut self, c: u16, dir: usize) {
        let now = self.eng.now();
        let mut out = std::mem::take(&mut self.scratch);
        let (src, dst) = {
            let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
            let (tx, rx) =
                if dir == 0 { (&mut ch.req, &mut ch.rsp) } else { (&mut ch.rsp, &mut ch.req) };
            tx.steal_piggy_from(rx);
            tx.pump(now, &mut out);
            (ch.src, ch.dst)
        };
        let landing = if dir == 0 { dst } else { src };
        for (at, f) in out.drain(..) {
            // hop latency accrues to the node the frame lands at —
            // intentionally NOT a span Launch mark: chan pumps re-send
            // translated ids, and retransmit-episode accounting belongs
            // to the client-side link only
            self.nodes[landing as usize].hop_lat.record_dur(at.since(now));
            let ev = if dir == 0 {
                Ev::FabLandReq(c, Box::new(f))
            } else {
                Ev::FabLandRsp(c, Box::new(f))
            };
            self.eng.schedule_at(at, ev);
        }
        self.scratch = out;
        self.arm_chan_retx(c, dir);
    }

    /// A forwarded request lands at the far home hop.
    fn fab_land_req(&mut self, c: u16, frame: Box<Frame>) {
        let now = self.eng.now();
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        let mut delivered = std::mem::take(&mut self.rx_frames);
        let mut ctls = std::mem::take(&mut self.rx_ctls);
        let (h, src) = {
            let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
            if let Some((vc, seq)) = frame.ack {
                ch.rsp.on_control(now, Control::VcAck(vc, seq));
            }
            ch.req.deliver(*frame, &mut delivered, &mut ctls);
            (ch.dst, ch.src)
        };
        for ctl in ctls.drain(..) {
            self.eng.schedule(ctrl, Ev::FabCtlReq(c, ctl));
        }
        self.rx_ctls = ctls;
        self.arm_chan_ack_flush(c, 0);
        for f in delivered.drain(..) {
            let home = self.interleave.home_of(f.msg.addr);
            if home == h {
                self.admit_frame(h, src, f, Source::Chan(c));
            } else {
                // the line migrated while this request crossed the
                // fabric: free the channel credit and chase the new home
                self.nodes[h as usize].counters.inc("fab_late_reforward");
                self.eng.schedule(ctrl, Ev::FabCreditReq(c, f.vc));
                let mut msg = f.msg;
                let true_src = if IdTranslator::is_translated(msg.id) {
                    let (s0, orig) = self.xlat.resolve(msg.id).expect("translated id pending");
                    msg.id = orig;
                    s0
                } else {
                    src
                };
                self.eng.schedule(ctrl, Ev::FabInject(home, Box::new(msg), true_src));
            }
        }
        self.rx_frames = delivered;
    }

    /// A response (or home-initiated fwd) lands back at the requesting
    /// node's client.
    fn fab_land_rsp(&mut self, c: u16, frame: Box<Frame>) {
        let now = self.eng.now();
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        let mut delivered = std::mem::take(&mut self.rx_frames);
        let mut ctls = std::mem::take(&mut self.rx_ctls);
        let s = {
            let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
            if let Some((vc, seq)) = frame.ack {
                ch.req.on_control(now, Control::VcAck(vc, seq));
            }
            ch.rsp.deliver(*frame, &mut delivered, &mut ctls);
            ch.src
        };
        for ctl in ctls.drain(..) {
            self.eng.schedule(ctrl, Ev::FabCtlRsp(c, ctl));
        }
        self.rx_ctls = ctls;
        self.arm_chan_ack_flush(c, 1);
        let mut sent = false;
        let mut fills: Vec<LineAddr> = Vec::new();
        for f in delivered.drain(..) {
            self.eng.schedule(ctrl, Ev::FabCreditRsp(c, f.vc));
            if let Some(sp) = self.obs.as_mut().and_then(|o| o.spans.as_mut()) {
                if matches!(f.msg.kind, MsgKind::CohRsp { .. }) {
                    sp.complete(now, span_key(s, f.msg.id.0));
                }
            }
            let fx = {
                let cell = &mut self.nodes[s as usize];
                cell.remote.on_message(f.msg, &mut cell.cache)
            };
            for e in fx {
                match e {
                    RemoteEffect::Send(m) => {
                        self.offer_home(s, m);
                        sent = true;
                    }
                    RemoteEffect::Filled { addr } => fills.push(addr),
                    RemoteEffect::Stalled => {}
                    RemoteEffect::ForeignVictim(_) => {
                        self.nodes[s as usize].counters.inc("foreign_victim")
                    }
                }
            }
        }
        self.rx_frames = delivered;
        if sent {
            self.pump_home(s);
        }
        for a in fills {
            self.wake(s, a);
        }
    }

    fn on_chan_retx(&mut self, c: u16, dir: usize) {
        {
            let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
            ch.retx_pending[dir] = false;
            let ing = if dir == 0 { &mut ch.req } else { &mut ch.rsp };
            if ing.rel_unacked() == 0 {
                return;
            }
            if ing.rel_acked() == ch.retx_seen_acked[dir] {
                ing.rel_force_replay();
            }
        }
        self.pump_chan(c, dir);
    }

    fn arm_chan_retx(&mut self, c: u16, dir: usize) {
        let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
        let ing = if dir == 0 { &ch.req } else { &ch.rsp };
        let Some(rto) = ing.link.rel_rto() else { return };
        if ing.rel_unacked() == 0 || ch.retx_pending[dir] {
            return;
        }
        ch.retx_seen_acked[dir] = ing.rel_acked();
        ch.retx_pending[dir] = true;
        self.eng.schedule(rto, if dir == 0 { Ev::FabRetxReq(c) } else { Ev::FabRetxRsp(c) });
    }

    fn on_chan_ack_flush(&mut self, c: u16, dir: usize) {
        let ctrl = self.cfg.ol.machine.ctrl_latency;
        self.chans[c as usize].as_mut().expect("off-diagonal").ack_flush_pending[dir] = false;
        loop {
            let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
            let ing = if dir == 0 { &mut ch.req } else { &mut ch.rsp };
            let Some((vc, seq)) = ing.take_piggy_ack() else { break };
            let ctl = Control::VcAck(vc, seq);
            self.eng.schedule(
                ctrl,
                if dir == 0 { Ev::FabCtlReq(c, ctl) } else { Ev::FabCtlRsp(c, ctl) },
            );
        }
    }

    fn arm_chan_ack_flush(&mut self, c: u16, dir: usize) {
        let ch = self.chans[c as usize].as_mut().expect("off-diagonal");
        let ing = if dir == 0 { &ch.req } else { &ch.rsp };
        if ch.ack_flush_pending[dir] || !ing.rel_has_ack_debt() {
            return;
        }
        ch.ack_flush_pending[dir] = true;
        self.eng.schedule(
            crate::transport::rel::ACK_FLUSH_DELAY,
            if dir == 0 { Ev::FabAckFlushReq(c) } else { Ev::FabAckFlushRsp(c) },
        );
    }

    // -- reporting ----------------------------------------------------------

    fn report(self) -> FabricReport {
        let sim_time = self.eng.now();
        let mut lat = Histogram::new();
        let mut hop_lat = Histogram::new();
        let mut counters = Counters::new();
        let mut per_node = Vec::with_capacity(self.nodes.len());
        for (i, cell) in self.nodes.into_iter().enumerate() {
            // fabric-wide distributions are the per-node histograms
            // merged — no sample is recorded twice
            lat.merge(&cell.lat);
            hop_lat.merge(&cell.hop_lat);
            let mut nc = cell.dcs.counters();
            for (k, v) in cell.remote.stats.iter() {
                nc.add(k, v);
            }
            for (k, v) in cell.counters.iter() {
                nc.add(k, v);
            }
            nc.add("kvs_lookups", cell.kvs.served);
            let frames_sent = |ing: &FramedIngress| match ing.link.rel.as_ref() {
                Some(r) => r.tx.sent,
                None => ing.link.tx.sent,
            };
            nc.add("frames_to_home", frames_sent(&cell.to_home));
            nc.add("frames_to_cpu", frames_sent(&cell.to_cpu));
            nc.add("home_credit_stalls", cell.to_home.credit_stalls);
            for (k, v) in nc.iter() {
                counters.add(k, v);
            }
            per_node.push(FabricNodeReport {
                node: i,
                completed: cell.completed,
                lat: cell.lat,
                fills_local: nc.get("fab_fills_local"),
                fills_remote: nc.get("fab_fills_remote"),
                migrations_in: nc.get("fab_migrations_in"),
                migrations_out: nc.get("fab_migrations_out"),
                credit_stalls: cell.to_home.credit_stalls,
                counters: nc,
            });
        }
        let delivered_per_s = if sim_time.ps() == 0 {
            0.0
        } else {
            self.completed_total as f64 / sim_time.as_secs()
        };
        FabricReport {
            scenario: self.scenario_name,
            nodes: self.cfg.nodes as usize,
            migrate: self.cfg.migrate,
            offered_per_s: self.cfg.ol.rate_per_s * self.cfg.nodes as f64,
            delivered_per_s,
            completed: self.completed_total,
            sim_time,
            lat,
            hop_lat,
            fills_local: counters.get("fab_fills_local"),
            fills_remote: counters.get("fab_fills_remote"),
            migrations: counters.get("fab_migrations_in"),
            moved_lines: self.interleave.moved_lines(),
            events: self.eng.dispatched,
            per_node,
            counters,
        }
    }
}

/// Convenience: run `scenario` on a fresh fabric.
pub fn run(cfg: FabricConfig, scenario: &Scenario) -> FabricReport {
    Fabric::new(cfg, scenario).run()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_node_smoke() {
        let sc = Scenario::preset("uniform", 1 << 10, 0.99).expect("preset");
        let cfg = FabricConfig {
            nodes: 2,
            ol: OpenLoopConfig { rate_per_s: 4e6, ops: 800, ..Default::default() },
            ..Default::default()
        };
        let (r, d1) = Fabric::new(cfg, &sc).run_settled();
        assert_eq!(r.completed, 800);
        assert_eq!(r.lat.count(), 800);
        assert_eq!(r.per_node.len(), 2);
        assert!(r.per_node.iter().all(|n| n.completed > 0), "{:?}", r.per_node);
        // the interleave scatters each window across both homes, so
        // roughly half the fills cross the fabric
        assert!(r.fills_remote > 0, "{:?}", r.counters);
        assert!(r.fills_local > 0, "{:?}", r.counters);
        assert!(r.hop_lat.count() > 0, "two-hop fills must cross the fabric");
        assert_eq!(r.migrations, 0, "migration is off");
        // bit-reproducible: same seed, same settled state
        let (r2, d2) = Fabric::new(cfg, &sc).run_settled();
        assert_eq!(d1, d2);
        assert_eq!(r.sim_time, r2.sim_time);
        assert_eq!(r.events, r2.events);
    }

    #[test]
    fn migration_moves_hot_lines_toward_their_talker() {
        let sc = Scenario::preset("hot-kvs", 1 << 10, 0.99).expect("preset");
        let mk = |migrate: bool| {
            let cfg = FabricConfig {
                nodes: 2,
                migrate,
                threshold: 4,
                ol: OpenLoopConfig { rate_per_s: 4e6, ops: 2_500, ..Default::default() },
                ..Default::default()
            };
            Fabric::new(cfg, &sc).run()
        };
        let off = mk(false);
        let on = mk(true);
        assert_eq!(off.completed, 2_500);
        assert_eq!(on.completed, 2_500, "migration must not lose operations");
        assert!(on.migrations > 0, "hot remote-homed lines must move: {:?}", on.counters);
        assert!(on.moved_lines > 0);
        // every migrated line turns its two-hop fills into local ones
        assert!(
            on.fills_remote < off.fills_remote,
            "migration must cut remote fills: {} vs {}",
            on.fills_remote,
            off.fills_remote
        );
    }
}
