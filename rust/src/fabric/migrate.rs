//! Home-migration bookkeeping: talker accounting (who keeps asking for
//! a line), the quiesce state of lines mid-move, and the parking lot
//! for requests that arrive during a move.
//!
//! The protocol itself (when a move may commit, how parked requests are
//! re-homed) lives in the fabric host; this module is the pure state so
//! it can be unit-tested without an event loop.

use std::collections::VecDeque;

use crate::proto::messages::{LineAddr, Message};
use crate::rustc_hash::FxHashMap as HashMap;

/// Per-line, per-source request counting plus the in-flight move state.
#[derive(Debug, Default)]
pub struct Migrator {
    /// Response-needing requests seen per (line, source node) since the
    /// line last moved.
    talkers: HashMap<LineAddr, HashMap<u8, u32>>,
    /// Lines mid-move -> target node. While present, new requests for
    /// the line park instead of entering the directory.
    migrating: HashMap<LineAddr, u8>,
    /// Requests (source node, message with its *original* id) that
    /// arrived mid-move, in arrival order.
    parked: HashMap<LineAddr, VecDeque<(u8, Message)>>,
    /// Messages for the line currently admitted into a directory and
    /// not yet serviced; a move can only commit at zero.
    live: HashMap<LineAddr, u32>,
}

impl Migrator {
    pub fn new() -> Migrator {
        Migrator::default()
    }

    /// Count a response-needing request for `addr` from `src`. Returns
    /// `true` when this request should *trigger* a move of `addr` to
    /// `src`: the count reached `threshold`, `src` is not already the
    /// home, and `src` dominates every other talker by at least 2x (a
    /// line two nodes fight over stays put rather than ping-ponging).
    pub fn note(&mut self, addr: LineAddr, src: u8, home: u8, threshold: u32) -> bool {
        let by_src = self.talkers.entry(addr).or_default();
        let n = by_src.entry(src).or_insert(0);
        *n += 1;
        let n = *n;
        if src == home || n < threshold || self.migrating.contains_key(&addr) {
            return false;
        }
        by_src.iter().all(|(&s, &c)| s == src || n >= 2 * c)
    }

    pub fn begin(&mut self, addr: LineAddr, target: u8) {
        let prev = self.migrating.insert(addr, target);
        debug_assert!(prev.is_none(), "line already migrating");
    }

    pub fn target_of(&self, addr: LineAddr) -> Option<u8> {
        self.migrating.get(&addr).copied()
    }

    pub fn park(&mut self, addr: LineAddr, src: u8, msg: Message) {
        self.parked.entry(addr).or_default().push_back((src, msg));
    }

    pub fn parked_count(&self, addr: LineAddr) -> usize {
        self.parked.get(&addr).map_or(0, |q| q.len())
    }

    /// Take the parking lot for `addr` (commit or abort), in arrival
    /// order.
    pub fn take_parked(&mut self, addr: LineAddr) -> VecDeque<(u8, Message)> {
        self.parked.remove(&addr).unwrap_or_default()
    }

    /// A message for `addr` entered a directory.
    pub fn live_inc(&mut self, addr: LineAddr) {
        *self.live.entry(addr).or_insert(0) += 1;
    }

    /// A message for `addr` finished service; returns the remaining
    /// live count. A decrement without a matching increment is an
    /// invariant violation, not a recoverable state: returning 0 here
    /// would open the zero-live commit gate early and let a move commit
    /// with a request still inside the directory — so it panics in
    /// release builds too.
    pub fn live_dec(&mut self, addr: LineAddr) -> u32 {
        match self.live.get_mut(&addr) {
            Some(n) => {
                *n -= 1;
                let left = *n;
                if left == 0 {
                    self.live.remove(&addr);
                }
                left
            }
            None => panic!("live_dec without live_inc for {addr}"),
        }
    }

    pub fn live(&self, addr: LineAddr) -> u32 {
        self.live.get(&addr).copied().unwrap_or(0)
    }

    /// The move of `addr` is over (committed or aborted): drop its move
    /// state and talker history so accounting restarts fresh at the new
    /// home.
    pub fn end(&mut self, addr: LineAddr) {
        self.migrating.remove(&addr);
        self.talkers.remove(&addr);
        debug_assert!(!self.parked.contains_key(&addr), "ending a move with parked requests");
    }

    /// Lines currently mid-move (diagnostics / settle assertions).
    pub fn in_flight(&self) -> usize {
        self.migrating.len()
    }

    /// Snapshot of the in-flight moves `(line, target)` — the failover
    /// path walks this to cancel moves touching a dead node.
    pub fn moves(&self) -> Vec<(LineAddr, u8)> {
        self.migrating.iter().map(|(&a, &t)| (a, t)).collect()
    }

    /// Drop every parked request sourced by `src` (a dead node's
    /// requests are abandoned, not replayed); returns how many were
    /// dropped.
    pub fn drop_parked_from(&mut self, src: u8) -> u64 {
        let mut dropped = 0;
        self.parked.retain(|_, q| {
            let before = q.len();
            q.retain(|&(s, _)| s != src);
            dropped += (before - q.len()) as u64;
            !q.is_empty()
        });
        dropped
    }

    /// Forget everything known about `addr` — talker history and live
    /// accounting. Used when a line is force-re-homed around a dead
    /// node: live counts at the dead home are meaningless and talker
    /// history must restart fresh at the survivor.
    pub fn forget(&mut self, addr: LineAddr) {
        self.talkers.remove(&addr);
        self.live.remove(&addr);
        debug_assert!(!self.migrating.contains_key(&addr), "forget during a move");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::{CohOp, ReqId};
    use crate::proto::states::Node;

    #[test]
    fn triggers_at_threshold_for_dominant_remote_talker() {
        let mut m = Migrator::new();
        let a = LineAddr(9);
        // two requests below threshold: no trigger
        assert!(!m.note(a, 1, 0, 3));
        assert!(!m.note(a, 1, 0, 3));
        // third reaches threshold, src 1 dominates (sole talker)
        assert!(m.note(a, 1, 0, 3));
        // requests from the line's own home never trigger
        let b = LineAddr(10);
        for _ in 0..10 {
            assert!(!m.note(b, 0, 0, 3));
        }
    }

    #[test]
    fn contended_lines_stay_put() {
        let mut m = Migrator::new();
        let a = LineAddr(5);
        // two nodes alternate: neither ever doubles the other
        for _ in 0..20 {
            assert!(!m.note(a, 1, 0, 3), "contended line must not ping-pong");
            assert!(!m.note(a, 2, 0, 3), "contended line must not ping-pong");
        }
    }

    #[test]
    fn live_and_park_bookkeeping() {
        let mut m = Migrator::new();
        let a = LineAddr(7);
        m.live_inc(a);
        m.live_inc(a);
        assert_eq!(m.live(a), 2);
        assert_eq!(m.live_dec(a), 1);
        assert_eq!(m.live_dec(a), 0);
        assert_eq!(m.live(a), 0);

        m.begin(a, 2);
        assert_eq!(m.target_of(a), Some(2));
        let msg = Message::coh_req(ReqId(1), Node::Remote, CohOp::ReadShared, a);
        m.park(a, 1, msg);
        assert_eq!(m.parked_count(a), 1);
        let q = m.take_parked(a);
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].0, 1);
        m.end(a);
        assert_eq!(m.target_of(a), None);
        assert_eq!(m.in_flight(), 0);
        // talker history restarted: counting begins again
        assert!(!m.note(a, 1, 0, 2));
        assert!(m.note(a, 1, 0, 2));
    }

    /// Regression (bugfix): an unmatched `live_dec` used to be a
    /// `debug_assert` + silent `0` in release builds — which is exactly
    /// the value that opens the zero-live migration-commit gate. It is
    /// an invariant violation and must die loudly in every build.
    #[test]
    #[should_panic(expected = "live_dec without live_inc")]
    fn unmatched_live_dec_panics_in_all_builds() {
        let mut m = Migrator::new();
        m.live_dec(LineAddr(3));
    }

    #[test]
    fn parked_requests_keep_arrival_order_and_dead_sources_drop() {
        let mut m = Migrator::new();
        let a = LineAddr(11);
        m.begin(a, 2);
        for (i, src) in [1u8, 3, 1, 2, 3].iter().enumerate() {
            let msg = Message::coh_req(ReqId(i as u32), Node::Remote, CohOp::ReadShared, a);
            m.park(a, *src, msg);
        }
        assert_eq!(m.drop_parked_from(3), 2, "both of node 3's parked requests drop");
        let q = m.take_parked(a);
        let order: Vec<(u8, u32)> = q.iter().map(|(s, msg)| (*s, msg.id.0)).collect();
        // survivors keep their exact arrival order (ids 0, 2, 3)
        assert_eq!(order, vec![(1, 0), (1, 2), (2, 3)]);
        m.end(a);
    }

    #[test]
    fn forget_clears_talkers_and_live() {
        let mut m = Migrator::new();
        let a = LineAddr(4);
        for _ in 0..5 {
            m.note(a, 1, 0, 100);
        }
        m.live_inc(a);
        m.forget(a);
        assert_eq!(m.live(a), 0);
        // talker history is gone: threshold counting restarts
        assert!(!m.note(a, 1, 0, 2));
        assert!(m.note(a, 1, 0, 2));
    }
}
