//! Routing primitives for the inter-node fabric: the global address
//! interleave (every line has exactly one home node) and the request-id
//! translator that keeps per-node `ReqId` spaces from colliding once
//! requests from N independent clients meet at one home directory.

use crate::proto::messages::{LineAddr, Message, ReqId};
use crate::rustc_hash::FxHashMap as HashMap;

/// The global address interleave. The *natural* home of a line is
/// `addr % nodes` — a static, stateless map every node computes
/// identically — with a sparse override table on top recording lines
/// that home migration has moved. A line therefore always has exactly
/// one home: the override if present, the natural home otherwise.
///
/// After [`Interleave::mark_dead`] the natural map is patched around
/// the dead node: lines whose natural home died re-interleave
/// deterministically across the survivors (`survivors[addr % (N-1)]`),
/// and overrides may never point at the dead node again.
#[derive(Debug, Clone)]
pub struct Interleave {
    nodes: u8,
    /// Lines whose home migration moved off the natural node.
    overrides: HashMap<LineAddr, u8>,
    /// The one failed node, if any, and the surviving nodes in index
    /// order (the re-interleave target list).
    dead: Option<u8>,
    survivors: Vec<u8>,
}

impl Interleave {
    pub fn new(nodes: u8) -> Interleave {
        assert!(nodes >= 1, "fabric needs at least one node");
        Interleave { nodes, overrides: HashMap::default(), dead: None, survivors: Vec::new() }
    }

    pub fn nodes(&self) -> u8 {
        self.nodes
    }

    pub fn dead(&self) -> Option<u8> {
        self.dead
    }

    /// The home `addr` falls back to with no override in play.
    fn natural_of(&self, addr: LineAddr) -> u8 {
        let n = (addr.0 % self.nodes as u64) as u8;
        match self.dead {
            Some(d) if n == d => self.survivors[(addr.0 % self.survivors.len() as u64) as usize],
            _ => n,
        }
    }

    /// The one home node of `addr`.
    pub fn home_of(&self, addr: LineAddr) -> u8 {
        match self.overrides.get(&addr) {
            Some(&n) => n,
            None => self.natural_of(addr),
        }
    }

    /// Re-home `addr` to `node` (migration commit). Overrides that put a
    /// line back on its natural home are dropped, keeping the table
    /// sparse under churn.
    pub fn set_home(&mut self, addr: LineAddr, node: u8) {
        debug_assert!(node < self.nodes);
        debug_assert!(Some(node) != self.dead, "re-homing a line onto a dead node");
        if node == self.natural_of(addr) {
            self.overrides.remove(&addr);
        } else {
            self.overrides.insert(addr, node);
        }
    }

    /// Declare `dead` failed: every line it homed — naturally or via a
    /// migration override — re-homes deterministically across the
    /// survivors, and the node can never be a home again. Single
    /// failure only (a second distinct death is unsupported).
    pub fn mark_dead(&mut self, dead: u8) {
        assert!(dead < self.nodes, "dead node out of range");
        assert!(self.nodes >= 2, "a 1-node fabric cannot lose its only node");
        assert!(self.dead.is_none(), "only one node failure is supported");
        self.dead = Some(dead);
        self.survivors = (0..self.nodes).filter(|&n| n != dead).collect();
        // overrides that pointed at the dead node dissolve: the line
        // returns to its (patched) natural placement
        self.overrides.retain(|_, &mut n| n != dead);
        // overrides that now AGREE with the patched natural map would
        // stop being "moved"; collapse them to keep moved_lines honest
        let survivors = std::mem::take(&mut self.survivors);
        self.overrides.retain(|&a, &mut n| {
            let nat = (a.0 % self.nodes as u64) as u8;
            let eff = if nat == dead {
                survivors[(a.0 % survivors.len() as u64) as usize]
            } else {
                nat
            };
            n != eff
        });
        self.survivors = survivors;
    }

    /// Lines currently living away from their natural home.
    pub fn moved_lines(&self) -> usize {
        self.overrides.len()
    }
}

/// Translated ids carry this bit so the home side can tell a forwarded
/// request from one issued by its own local client (whose ids come from
/// the per-node remote agents and stay below 2^31).
pub const TRANSLATED_BIT: u32 = 0x8000_0000;

/// One pending forward at the translation point: where the request came
/// from, the id it carried there, the home it was sent to, and a copy
/// of the request itself (with its *original* id) so the fabric can
/// re-issue it against a new home if the old one dies mid-flight.
#[derive(Debug, Clone)]
pub struct PendingXlat {
    pub src: u8,
    pub orig: ReqId,
    pub home: u8,
    pub msg: Message,
}

/// Rewrites request ids at the fabric-forward point. Each node's remote
/// agent numbers its transactions independently, so two nodes' requests
/// meeting at one home would collide; the forwarding router swaps the
/// original id for a fabric-unique one and remembers the
/// [`PendingXlat`] until the response *lands back at the source*
/// ([`IdTranslator::complete`]). Keeping entries alive until landing —
/// not merely until the response is generated — is what makes failover
/// replay exactly-once: an entry is pending if and only if the source
/// has not received its response, so replaying exactly the entries
/// homed at a dead node re-issues every unanswered request and nothing
/// else.
#[derive(Debug, Default)]
pub struct IdTranslator {
    next: u32,
    pending: HashMap<u32, PendingXlat>,
    /// Reverse index for completion at response landing.
    by_orig: HashMap<(u8, u32), u32>,
}

impl IdTranslator {
    pub fn new() -> IdTranslator {
        IdTranslator::default()
    }

    pub fn is_translated(id: ReqId) -> bool {
        id.0 & TRANSLATED_BIT != 0
    }

    /// Allocate a fabric id for `msg` (carrying its original id) sent
    /// by `src` toward `home`. If the 31-bit id space wraps onto an id
    /// that is still pending, the allocator skips forward to the next
    /// free id instead of silently overwriting the older mapping (which
    /// would lose the original requester's response).
    pub fn translate(&mut self, src: u8, home: u8, msg: &Message) -> ReqId {
        let orig = msg.id;
        debug_assert!(!Self::is_translated(orig), "double translation");
        let mut probes: u32 = 0;
        let id = loop {
            let cand = TRANSLATED_BIT | self.next;
            self.next = (self.next + 1) & !TRANSLATED_BIT;
            if !self.pending.contains_key(&cand) {
                break cand;
            }
            probes += 1;
            assert!(probes < TRANSLATED_BIT, "fabric id space exhausted: every id pending");
        };
        self.pending.insert(id, PendingXlat { src, orig, home, msg: msg.clone() });
        let stale = self.by_orig.insert((src, orig.0), id);
        debug_assert!(stale.is_none(), "source {src} re-used id {orig:?} while pending");
        ReqId(id)
    }

    /// Look up a pending translation without consuming it (response
    /// generation, span marks at delivery time).
    pub fn peek(&self, id: ReqId) -> Option<(u8, ReqId)> {
        self.pending.get(&id.0).map(|p| (p.src, p.orig))
    }

    /// Consume a pending translation (the parked or mid-flight request
    /// is being re-issued and will be re-translated).
    pub fn resolve(&mut self, id: ReqId) -> Option<(u8, ReqId)> {
        let p = self.pending.remove(&id.0)?;
        self.by_orig.remove(&(p.src, p.orig.0));
        Some((p.src, p.orig))
    }

    /// The response for `(src, orig)` landed at the source: retire the
    /// mapping. Returns whether an entry was pending (false for
    /// responses whose request was never translated, e.g. local fills).
    pub fn complete(&mut self, src: u8, orig: ReqId) -> bool {
        match self.by_orig.remove(&(src, orig.0)) {
            Some(fab) => {
                let p = self.pending.remove(&fab);
                debug_assert!(p.is_some(), "by_orig points at a missing pending entry");
                true
            }
            None => false,
        }
    }

    /// Sweep the table after `dead` fails. Entries *homed* at the dead
    /// node are unanswered requests from surviving sources — returned
    /// (in fabric-id allocation order, i.e. roughly issue order) for
    /// replay against the lines' new homes. Entries *sourced* by the
    /// dead node no longer have a requester to answer — dropped; the
    /// count comes back for accounting.
    pub fn on_node_dead(&mut self, dead: u8) -> (Vec<PendingXlat>, u64) {
        let mut replay: Vec<(u32, PendingXlat)> = Vec::new();
        let mut dropped = 0u64;
        self.pending.retain(|&id, p| {
            if p.src == dead {
                dropped += 1;
                false
            } else if p.home == dead {
                replay.push((id, p.clone()));
                false
            } else {
                true
            }
        });
        replay.sort_by_key(|&(id, _)| id);
        let replay: Vec<PendingXlat> = replay.into_iter().map(|(_, p)| p).collect();
        for p in &replay {
            self.by_orig.remove(&(p.src, p.orig.0));
        }
        self.by_orig.retain(|&(src, _), _| src != dead);
        (replay, dropped)
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::messages::CohOp;
    use crate::proto::states::Node;

    fn req(id: u32, addr: u64) -> Message {
        Message::coh_req(ReqId(id), Node::Remote, CohOp::ReadShared, LineAddr(addr))
    }

    #[test]
    fn every_line_has_exactly_one_home() {
        for nodes in [1u8, 2, 4] {
            let il = Interleave::new(nodes);
            for a in 0..4096u64 {
                let h = il.home_of(LineAddr(a));
                assert!(h < nodes);
                // deterministic: asking twice gives the same answer
                assert_eq!(h, il.home_of(LineAddr(a)));
            }
        }
    }

    #[test]
    fn overrides_rehome_and_collapse_when_natural() {
        let mut il = Interleave::new(4);
        let a = LineAddr(6); // natural home 2
        assert_eq!(il.home_of(a), 2);
        il.set_home(a, 3);
        assert_eq!(il.home_of(a), 3);
        assert_eq!(il.moved_lines(), 1);
        // moving it back to the natural home drops the override
        il.set_home(a, 2);
        assert_eq!(il.home_of(a), 2);
        assert_eq!(il.moved_lines(), 0);
    }

    #[test]
    fn mark_dead_reinterleaves_exactly_the_dead_nodes_lines() {
        let mut il = Interleave::new(3);
        // one migration override onto the doomed node, one off it
        il.set_home(LineAddr(5), 1); // natural 2 -> 1 (dissolves on death)
        il.set_home(LineAddr(6), 2); // natural 0 -> 2 (survives)
        let before: Vec<u8> = (0..64).map(|a| il.home_of(LineAddr(a))).collect();
        il.mark_dead(1);
        assert_eq!(il.dead(), Some(1));
        for a in 0..64u64 {
            let h = il.home_of(LineAddr(a));
            assert_ne!(h, 1, "line {a} still homed at the dead node");
            assert!(h < 3);
            // lines the dead node never homed keep their placement
            if before[a as usize] != 1 {
                assert_eq!(h, before[a as usize], "line {a} moved needlessly");
            }
        }
        // the surviving override is untouched
        assert_eq!(il.home_of(LineAddr(6)), 2);
        // deterministic: the re-interleave is a pure function of addr
        let mut il2 = Interleave::new(3);
        il2.mark_dead(1);
        for a in 0..64u64 {
            if (a % 3) == 1 {
                assert_eq!(il.home_of(LineAddr(a)), il2.home_of(LineAddr(a)));
            }
        }
    }

    #[test]
    fn translator_round_trips_and_flags() {
        let mut t = IdTranslator::new();
        let m = req(42, 9);
        let fab = t.translate(3, 0, &m);
        assert!(IdTranslator::is_translated(fab));
        assert!(!IdTranslator::is_translated(m.id));
        assert_eq!(t.peek(fab), Some((3, ReqId(42))));
        assert_eq!(t.pending(), 1);
        assert_eq!(t.resolve(fab), Some((3, ReqId(42))));
        assert_eq!(t.pending(), 0);
        assert_eq!(t.resolve(fab), None, "resolution consumes the mapping");
        // ids stay unique while earlier ones are pending
        let a = t.translate(0, 0, &req(1, 2));
        let b = t.translate(1, 0, &req(1, 2));
        assert_ne!(a, b);
    }

    #[test]
    fn complete_retires_by_source_and_original_id() {
        let mut t = IdTranslator::new();
        t.translate(2, 0, &req(7, 3));
        assert!(t.complete(2, ReqId(7)));
        assert_eq!(t.pending(), 0);
        assert!(!t.complete(2, ReqId(7)), "already retired");
        assert!(!t.complete(1, ReqId(7)), "wrong source never matches");
    }

    /// Regression (bugfix): a 31-bit id-space wrap onto a still-pending
    /// id used to be a `debug_assert` + silent `HashMap::insert`
    /// overwrite in release builds, losing the older requester's
    /// response. The allocator must skip to the next free id.
    #[test]
    fn wrap_skips_pending_ids_instead_of_overwriting() {
        let mut t = IdTranslator::new();
        // allocate the very last id of the space and keep it pending
        t.next = !TRANSLATED_BIT; // 0x7FFF_FFFF
        let last = t.translate(0, 1, &req(10, 4));
        assert_eq!(last.0, u32::MAX);
        // force the allocator to land on `last` again
        t.next = !TRANSLATED_BIT;
        let next = t.translate(1, 1, &req(11, 5));
        assert_eq!(next.0, TRANSLATED_BIT, "wrap must skip the pending id");
        // the older mapping survived intact
        assert_eq!(t.resolve(last), Some((0, ReqId(10))));
        assert_eq!(t.resolve(next), Some((1, ReqId(11))));
    }

    #[test]
    fn node_death_splits_pending_into_replay_and_dropped() {
        let mut t = IdTranslator::new();
        t.translate(0, 1, &req(1, 10)); // survivor -> dead home: replay
        t.translate(2, 1, &req(2, 11)); // survivor -> dead home: replay
        t.translate(1, 0, &req(3, 12)); // dead source: drop
        t.translate(0, 2, &req(4, 13)); // untouched
        let (replay, dropped) = t.on_node_dead(1);
        assert_eq!(dropped, 1);
        assert_eq!(replay.len(), 2);
        // replay comes back in allocation order with original ids
        assert_eq!((replay[0].src, replay[0].orig), (0, ReqId(1)));
        assert_eq!((replay[1].src, replay[1].orig), (2, ReqId(2)));
        assert_eq!(replay[0].msg.id, ReqId(1), "stored message keeps its original id");
        assert_eq!(t.pending(), 1, "entries not touching the dead node stay");
        // the survivors' by_orig slots are free again for re-issue
        let refab = t.translate(0, 2, &req(1, 10));
        assert!(IdTranslator::is_translated(refab));
    }
}
