//! Routing primitives for the inter-node fabric: the global address
//! interleave (every line has exactly one home node) and the request-id
//! translator that keeps per-node `ReqId` spaces from colliding once
//! requests from N independent clients meet at one home directory.

use crate::proto::messages::{LineAddr, ReqId};
use crate::rustc_hash::FxHashMap as HashMap;

/// The global address interleave. The *natural* home of a line is
/// `addr % nodes` — a static, stateless map every node computes
/// identically — with a sparse override table on top recording lines
/// that home migration has moved. A line therefore always has exactly
/// one home: the override if present, the natural home otherwise.
#[derive(Debug, Clone)]
pub struct Interleave {
    nodes: u8,
    /// Lines whose home migration moved off the natural node.
    overrides: HashMap<LineAddr, u8>,
}

impl Interleave {
    pub fn new(nodes: u8) -> Interleave {
        assert!(nodes >= 1, "fabric needs at least one node");
        Interleave { nodes, overrides: HashMap::default() }
    }

    pub fn nodes(&self) -> u8 {
        self.nodes
    }

    /// The one home node of `addr`.
    pub fn home_of(&self, addr: LineAddr) -> u8 {
        match self.overrides.get(&addr) {
            Some(&n) => n,
            None => (addr.0 % self.nodes as u64) as u8,
        }
    }

    /// Re-home `addr` to `node` (migration commit). Overrides that put a
    /// line back on its natural home are dropped, keeping the table
    /// sparse under churn.
    pub fn set_home(&mut self, addr: LineAddr, node: u8) {
        debug_assert!(node < self.nodes);
        if node == (addr.0 % self.nodes as u64) as u8 {
            self.overrides.remove(&addr);
        } else {
            self.overrides.insert(addr, node);
        }
    }

    /// Lines currently living away from their natural home.
    pub fn moved_lines(&self) -> usize {
        self.overrides.len()
    }
}

/// Translated ids carry this bit so the home side can tell a forwarded
/// request from one issued by its own local client (whose ids come from
/// the per-node remote agents and stay below 2^31).
pub const TRANSLATED_BIT: u32 = 0x8000_0000;

/// Rewrites request ids at the fabric-forward point. Each node's remote
/// agent numbers its transactions independently, so two nodes' requests
/// meeting at one home would collide; the forwarding router swaps the
/// original id for a fabric-unique one and remembers `(source node,
/// original id)` until the response is generated, where the mapping is
/// resolved and the original id restored (the source's remote agent
/// matches responses by id).
#[derive(Debug, Default)]
pub struct IdTranslator {
    next: u32,
    pending: HashMap<u32, (u8, ReqId)>,
}

impl IdTranslator {
    pub fn new() -> IdTranslator {
        IdTranslator::default()
    }

    pub fn is_translated(id: ReqId) -> bool {
        id.0 & TRANSLATED_BIT != 0
    }

    /// Allocate a fabric id for `(src, orig)`.
    pub fn translate(&mut self, src: u8, orig: ReqId) -> ReqId {
        debug_assert!(!Self::is_translated(orig), "double translation");
        let id = TRANSLATED_BIT | self.next;
        self.next = (self.next + 1) & !TRANSLATED_BIT;
        let prev = self.pending.insert(id, (src, orig));
        debug_assert!(prev.is_none(), "fabric id space wrapped while pending");
        ReqId(id)
    }

    /// Look up a pending translation without consuming it (span marks at
    /// delivery time).
    pub fn peek(&self, id: ReqId) -> Option<(u8, ReqId)> {
        self.pending.get(&id.0).copied()
    }

    /// Consume a pending translation (response generated, or the parked
    /// request is being re-homed).
    pub fn resolve(&mut self, id: ReqId) -> Option<(u8, ReqId)> {
        self.pending.remove(&id.0)
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_line_has_exactly_one_home() {
        for nodes in [1u8, 2, 4] {
            let il = Interleave::new(nodes);
            for a in 0..4096u64 {
                let h = il.home_of(LineAddr(a));
                assert!(h < nodes);
                // deterministic: asking twice gives the same answer
                assert_eq!(h, il.home_of(LineAddr(a)));
            }
        }
    }

    #[test]
    fn overrides_rehome_and_collapse_when_natural() {
        let mut il = Interleave::new(4);
        let a = LineAddr(6); // natural home 2
        assert_eq!(il.home_of(a), 2);
        il.set_home(a, 3);
        assert_eq!(il.home_of(a), 3);
        assert_eq!(il.moved_lines(), 1);
        // moving it back to the natural home drops the override
        il.set_home(a, 2);
        assert_eq!(il.home_of(a), 2);
        assert_eq!(il.moved_lines(), 0);
    }

    #[test]
    fn translator_round_trips_and_flags() {
        let mut t = IdTranslator::new();
        let orig = ReqId(42);
        let fab = t.translate(3, orig);
        assert!(IdTranslator::is_translated(fab));
        assert!(!IdTranslator::is_translated(orig));
        assert_eq!(t.peek(fab), Some((3, orig)));
        assert_eq!(t.pending(), 1);
        assert_eq!(t.resolve(fab), Some((3, orig)));
        assert_eq!(t.pending(), 0);
        assert_eq!(t.resolve(fab), None, "resolution consumes the mapping");
        // ids stay unique while earlier ones are pending
        let a = t.translate(0, ReqId(1));
        let b = t.translate(1, ReqId(1));
        assert_ne!(a, b);
    }
}
