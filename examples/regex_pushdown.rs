//! Regex pushdown with temporal locality (paper §5.6 + §5.7): run the
//! 48-engine regex operator, then demonstrate the §5.7 effect — an
//! application that re-reads expensive results gets them from its own
//! L1/L2, transparently, thanks to full coherence.
//!
//!     make artifacts && cargo run --release --example regex_pushdown

use eci::harness::{fig7, fig8, Scale};
use eci::runtime::Runtime;

fn main() -> eci::anyhow::Result<()> {
    let scale = Scale::from_env();
    let mut rt = Runtime::load_default().expect("artifacts missing — run `make artifacts`");

    let rows = scale.rows(5_120_000).max(40_000);
    println!("== regex pushdown: {rows} rows, pattern 'erro+r', 48 engines ==\n");
    for threads in [1usize, 8, 16] {
        let f = fig7::run_fpga(&mut rt, rows, 0.10, threads)?;
        let c = fig7::run_cpu(rows, 0.10, threads)?;
        println!(
            "threads {threads:>2}: FPGA {:>7.2}M rows/s vs CPU {:>6.2}M rows/s  ({:.1}x)",
            f.scan_rows_per_s / 1e6,
            c.scan_rows_per_s / 1e6,
            f.scan_rows_per_s / c.scan_rows_per_s
        );
    }

    println!("\n== temporal locality (§5.7): single core, recompute-on-miss region ==\n");
    let f8 = fig8::run(Scale::Ci);
    println!("reuse   reads/s      speedup-vs-no-reuse");
    for p in f8.points.iter().filter(|p| p.cache == "L1") {
        println!(
            "{:>4.0}x  {:>9.2}M   {:.1}x",
            p.reuse_factor,
            p.reads_per_s / 1e6,
            p.reads_per_s / f8.baseline_reads_per_s
        );
    }
    println!(
        "\nResults land in the CPU's caches invisibly to both sides; reuse \
         turns FPGA-recompute latency into L1 hits."
    );
    Ok(())
}
