//! End-to-end driver (DESIGN.md §5): boot the FULL stack — CPU socket
//! model, ECI transport, stateless smart memory controller whose datapath
//! is the AOT-compiled XLA kernels (JAX/Pallas -> HLO -> PJRT) — run
//! SELECT and regex pushdown queries from 16 simulated cores over a real
//! generated table, verify every returned row against the CPU baseline,
//! and report throughput/latency.
//!
//!     make artifacts && cargo run --release --example e2e_select_serve
//!
//! Scale with ECI_SCALE={ci,default,paper}.

use std::cell::RefCell;
use std::collections::HashSet;
use std::rc::Rc;

use eci::agents::dram::MemStore;
use eci::harness::Scale;
use eci::machine::{map, FpgaApp, Machine, MachineConfig, Workload};
use eci::memctl::{regex_row_cycles, FifoServer, ScanTiming};
use eci::operators::redfa::compile_regex;
use eci::operators::regex_op::{cpu_regex_scan, fpga_regex_scan};
use eci::operators::select::{cpu_select_scan, fpga_select_scan};
use eci::operators::table::{build_table, row_str, select_params, TableSpec};
use eci::proto::messages::{LineAddr, LINE_BYTES};
use eci::runtime::{Runtime, DFA_STATES};
use eci::sim::time::Duration;

fn main() -> eci::anyhow::Result<()> {
    let scale = Scale::from_env();
    let rows = scale.rows(5_120_000).max(40_000);
    let threads = 16;
    println!("== ECI end-to-end driver: {rows} rows, {threads} threads (scale {scale:?}) ==\n");

    let mut rt = Runtime::load_default()
        .expect("artifacts missing — run `make artifacts` first");

    // ---- build the table in simulated FPGA DRAM -------------------------
    let spec = TableSpec::new(rows, 0.10);
    let mut store = MemStore::new(map::TABLE_BASE, rows as usize * LINE_BYTES);
    build_table(&spec, &mut store);
    println!("table: {} MB in FPGA DRAM, 10% selectivity", rows * 128 / 1_000_000);

    // ======================= query 1: SELECT =============================
    let (x, y) = select_params(0.10);
    let t0 = std::time::Instant::now();
    let matches = fpga_select_scan(&mut rt, &store, map::TABLE_BASE, rows, x, y)?;
    println!(
        "\n[select] XLA kernel scanned {rows} rows in {:?} (host) -> {} matches",
        t0.elapsed(),
        matches.len()
    );
    // oracle: CPU baseline must agree exactly
    let oracle = cpu_select_scan(&store, map::TABLE_BASE, rows, x, y);
    assert_eq!(matches, oracle, "XLA kernel vs CPU baseline mismatch");
    println!("[select] kernel results verified against CPU baseline");

    let payloads: Vec<_> = matches
        .iter()
        .map(|&i| Box::new(store.read_line(LineAddr(map::TABLE_BASE.0 + i))))
        .collect();
    let expect: HashSet<[u8; 16]> = payloads
        .iter()
        .map(|p| p[0..16].try_into().unwrap())
        .collect();
    let fifo = FifoServer::new(rows, matches, payloads, |_| 1, ScanTiming::enzian(8), 64 << 10);
    let n_results = fifo.total_results();

    let cfg = MachineConfig::enzian_eci();
    let cpu_mem = MemStore::new(LineAddr(0), 1 << 20);
    let mut m = Machine::new(cfg, FpgaApp::Fifo(fifo), store, cpu_mem);
    m.config_block.set_select_params(x, y);
    // verify every line delivered into the LLC is a genuine match
    let seen = Rc::new(RefCell::new(0u64));
    {
        let seen = Rc::clone(&seen);
        m.verify_fill = Some(Box::new(move |_addr, data| {
            if data[0] == 0xFF && data[..8].iter().all(|&b| b == 0xFF) {
                return; // end marker
            }
            let key: [u8; 16] = data[0..16].try_into().unwrap();
            assert!(expect.contains(&key), "served a non-matching row");
            *seen.borrow_mut() += 1;
        }));
    }
    m.set_workload(Workload::FifoConsume { think: Duration::from_ns(5) }, threads);
    let r = m.run();
    assert_eq!(r.results as usize, n_results);
    assert_eq!(*seen.borrow() as usize, n_results);
    println!(
        "[select] served {} results over ECI: {:.1}M results/s, scan {:.1}M rows/s, \
         mean load {:.0} ns, link {:.2} GiB/s",
        r.results,
        r.results_per_s() / 1e6,
        rows as f64 / r.sim_time.as_secs() / 1e6,
        r.mean_load_ns(),
        r.remote_gib_per_s(),
    );

    // ======================= query 2: regex ==============================
    // rebuild the table store (the select machine consumed it)
    let mut store = MemStore::new(map::TABLE_BASE, rows as usize * LINE_BYTES);
    build_table(&spec, &mut store);
    let dfa = compile_regex(&spec.needle, DFA_STATES)?;
    let t0 = std::time::Instant::now();
    let matches = fpga_regex_scan(&mut rt, &store, map::TABLE_BASE, rows, &dfa)?;
    println!(
        "\n[regex]  XLA kernel ({}-state DFA for {:?}) matched {} rows in {:?} (host)",
        dfa.n_states(),
        spec.needle,
        matches.len(),
        t0.elapsed()
    );
    let oracle = cpu_regex_scan(&store, map::TABLE_BASE, rows, &dfa);
    assert_eq!(matches, oracle, "regex kernel vs CPU baseline mismatch");
    println!("[regex]  kernel results verified against CPU baseline");

    let payloads: Vec<_> = matches
        .iter()
        .map(|&i| Box::new(store.read_line(LineAddr(map::TABLE_BASE.0 + i))))
        .collect();
    let cycles: Vec<u64> = (0..rows)
        .map(|i| {
            let l = store.read_line(LineAddr(map::TABLE_BASE.0 + i));
            regex_row_cycles(&dfa, row_str(&l))
        })
        .collect();
    let fifo = FifoServer::new(
        rows,
        matches,
        payloads,
        move |r| cycles[r as usize],
        ScanTiming::enzian(48),
        64 << 10,
    );
    let n_results = fifo.total_results();
    let cpu_mem = MemStore::new(LineAddr(0), 1 << 20);
    let mut m = Machine::new(MachineConfig::enzian_eci(), FpgaApp::Fifo(fifo), store, cpu_mem);
    m.set_workload(Workload::FifoConsume { think: Duration::from_ns(5) }, threads);
    let r = m.run();
    assert_eq!(r.results as usize, n_results);
    println!(
        "[regex]  served {} results over ECI: {:.1}M results/s, scan {:.1}M rows/s, \
         mean load {:.0} ns",
        r.results,
        r.results_per_s() / 1e6,
        rows as f64 / r.sim_time.as_secs() / 1e6,
        r.mean_load_ns(),
    );

    println!("\nOK — all layers composed: Pallas/JAX kernels (AOT) -> PJRT -> memctl -> ECI -> CPU socket");
    Ok(())
}
