//! Quickstart: boot the two-node machine (full symmetric protocol), do
//! coherent reads and writebacks across the ECI link, and show the
//! message flow through the dissector.
//!
//!     cargo run --release --example quickstart

use std::cell::RefCell;
use std::rc::Rc;

use eci::agents::dram::MemStore;
use eci::machine::{map, Machine, MachineConfig, Workload};
use eci::proto::messages::LineAddr;
use eci::trace::capture::{Capture, Dir};
use eci::trace::dissector;

fn main() {
    // 1. a machine: ThunderX-1 socket <-> ECI link <-> FPGA home node
    let cfg = MachineConfig::enzian_eci();
    let mut fpga_mem = MemStore::new(map::TABLE_BASE, 1 << 20);
    let cpu_mem = MemStore::new(LineAddr(0), 1 << 20);

    // put recognizable data in FPGA memory
    for i in 0..64u64 {
        let mut line = [0u8; 128];
        line[0..8].copy_from_slice(&(0xECu64 << 56 | i).to_le_bytes());
        fpga_mem.write_line(LineAddr(map::TABLE_BASE.0 + i), &line);
    }

    let mut m = Machine::memory_node(cfg, fpga_mem, cpu_mem);

    // 2. capture the protocol traffic
    let capture = Rc::new(RefCell::new(Capture::new(32)));
    {
        let capture = Rc::clone(&capture);
        m.tap = Some(Box::new(move |t, to_fpga, msg| {
            let dir = if to_fpga { Dir::CpuToFpga } else { Dir::FpgaToCpu };
            capture.borrow_mut().record(t, dir, msg.clone());
        }));
    }

    // 3. verify every payload that crosses the link
    m.verify_fill = Some(Box::new(|addr, data| {
        let i = addr.0 - map::TABLE_BASE.0;
        let got = u64::from_le_bytes(data[0..8].try_into().unwrap());
        assert_eq!(got, 0xECu64 << 56 | i, "corrupted line {addr}");
    }));

    // 4. two cores stream 64 remote lines coherently
    m.set_workload(Workload::StreamRemote { lines: 64 }, 2);
    let report = m.run();

    println!("== quickstart: coherent remote reads over ECI ==\n");
    for c in capture.borrow().iter().take(12) {
        println!("{}", dissector::summary(c.time, &c.msg));
    }
    println!("  ... ({} messages total)\n", capture.borrow().total_seen);

    println!("simulated time : {}", report.sim_time);
    println!("remote data    : {} KiB, all payloads verified", report.remote_bytes / 1024);
    println!(
        "load latency   : mean {:.0} ns, p50 {:.0} ns, p99 {:.0} ns",
        report.mean_load_ns(),
        report.load_lat.p50() as f64 / 1e3,
        report.load_lat.p99() as f64 / 1e3,
    );
    println!("events run     : {}", report.events);
    println!("\nOK");
}
