//! KVS pointer-chase offload (paper §5.5, Fig. 4 topology): build a
//! separate-chaining hash table in FPGA DRAM, hash request keys through
//! the AOT XLA kernel, dispatch lookups over ECI to the 32-engine pool,
//! and compare against the CPU-local baseline — reproducing the paper's
//! *negative* result for this workload at one chain length.
//!
//!     make artifacts && cargo run --release --example kvs_pointer_chase

use eci::harness::fig6;
use eci::runtime::Runtime;

fn main() -> eci::anyhow::Result<()> {
    let mut rt = Runtime::load_default().expect("artifacts missing — run `make artifacts`");
    let entries = 131_072;
    let lookups = 20_000;
    println!("== KVS pointer-chase offload: {entries} entries, {lookups} lookups ==\n");
    println!("chain  FPGA keys/s   CPU keys/s   winner");
    for chain_len in [1u64, 4, 16, 64] {
        let f = fig6::run_fpga(&mut rt, entries, chain_len, 32, lookups)?;
        let c = fig6::run_cpu(entries, chain_len, 32, lookups);
        println!(
            "{chain_len:>5}  {:>10.2}M  {:>10.2}M   {}",
            f.keys_per_s / 1e6,
            c.keys_per_s / 1e6,
            if c.keys_per_s > f.keys_per_s { "CPU (paper's negative result)" } else { "FPGA" }
        );
    }
    println!(
        "\nThe offload loses: random DRAM latency dominates and the CPU's \
         caches+clocks win — but ECI made prototyping the experiment trivial \
         (the paper's own conclusion in §5.5)."
    );
    Ok(())
}
