//! Author an NFA protocol property in the checker's spec language, run it
//! online against live simulated traffic, dump the trace in the JSON and
//! EWF interchange formats, and show a violation being caught (paper §4.1
//! "Online tracing").
//!
//!     cargo run --release --example protocol_check

use std::cell::RefCell;
use std::rc::Rc;

use eci::agents::dram::MemStore;
use eci::machine::{map, Machine, MachineConfig, Workload};
use eci::proto::messages::{CohOp, LineAddr, Message, ReqId};
use eci::proto::states::Node;
use eci::sim::time::Time;
use eci::trace::capture::{Capture, Dir};
use eci::trace::checker::{NfaSpec, OnlineChecker};

/// A user-authored property: the stateless read-only home must never
/// issue home-initiated downgrades (§3.4 — it has no state to protect).
const MY_SPEC: &str = r#"
# the read-only home never initiates downgrades
nfa readonly_home_is_passive {
  start s;
  s: req * -> s;
  s: rsp * -> s;
  s: wb  * -> s;
  s: fwd * -> error "home-initiated downgrade from a stateless home";
  default ignore;
}
"#;

fn main() {
    let spec = NfaSpec::parse(MY_SPEC).expect("spec parses");
    println!("compiled NFA '{0}' ({1} states)\n", "readonly_home_is_passive", spec.state_count());
    let checker = Rc::new(RefCell::new(OnlineChecker::new(spec)));
    let capture = Rc::new(RefCell::new(Capture::new(1024)));

    // drive real traffic through a memory-node machine
    let cfg = MachineConfig::test_small();
    let fpga = MemStore::new(map::TABLE_BASE, 1 << 20);
    let cpu = MemStore::new(LineAddr(0), 1 << 20);
    let mut m = Machine::memory_node(cfg, fpga, cpu);
    {
        let checker = Rc::clone(&checker);
        let capture = Rc::clone(&capture);
        m.tap = Some(Box::new(move |t, to_fpga, msg| {
            checker.borrow_mut().observe(t, msg);
            capture.borrow_mut().record(
                t,
                if to_fpga { Dir::CpuToFpga } else { Dir::FpgaToCpu },
                msg.clone(),
            );
        }));
    }
    m.set_workload(Workload::StreamRemote { lines: 512 }, 4);
    let r = m.run();

    let c = checker.borrow();
    println!(
        "checked {} live messages over {} lines: {} violations",
        c.messages_checked,
        c.tracked_lines(),
        c.violations.len()
    );
    assert!(c.violations.is_empty());
    drop(c);

    // interchange dumps
    let json = capture.borrow().to_json().to_string();
    let ewf = capture.borrow().to_ewf();
    let back = Capture::from_ewf(&ewf).expect("EWF round-trip");
    println!("trace dumps: {} B JSON, {} B EWF ({} records round-tripped)", json.len(), ewf.len(), back.len());

    // inject the violation the property is about
    let bogus = Message::coh_req(
        ReqId(999),
        Node::Home,
        CohOp::FwdDowngradeI,
        LineAddr(map::TABLE_BASE.0 + 1),
    );
    checker.borrow_mut().observe(Time(r.sim_time.ps() + 1), &bogus);
    let c = checker.borrow();
    assert_eq!(c.violations.len(), 1);
    println!("\ninjected a FwdDowngradeI from the 'stateless' home:");
    for v in &c.violations {
        println!("  VIOLATION [{}] t={} {}: {}", v.spec, v.time, v.addr, v.detail);
    }
    println!("\nOK");
}
