"""Pure-jnp correctness oracles for the Pallas kernels.

These are the single source of truth for kernel semantics: pytest sweeps
shapes/dtypes (hypothesis) and asserts the Pallas kernels match these
bit-for-bit (integers) / allclose (floats). The Rust CPU baselines
re-implement the same definitions natively; `python/tests/test_abi.py`
pins the shared data layout.

Row ABI (shared with rust/src/operators):
  * 128-byte row = 32 little-endian f32 words for SELECT; attribute
    ``a`` = word 0, ``b`` = word 1.
  * regex string field = bytes 64..126 of the row (62 bytes), evaluated
    as int32 character codes 0..255.
  * KVS key = low 32 bits of the 8-byte key, as int32.
"""

import jax.numpy as jnp

# Fixed kernel geometry (mirrored in rust/src/runtime/artifacts.rs).
BATCH = 4096
STR_LEN = 62
DFA_STATES = 32
ROW_WORDS = 32

# Knuth's multiplicative constant 2654435761 as a wrapped int32.
HASH_MULT = jnp.int32(-1640531527)


def select_mask(rows, x, y):
    """SELECT * FROM S WHERE S.a > X AND S.b < Y  (paper §5.4).

    rows: [B, 32] f32; returns [B] int32 0/1 mask.
    """
    a = rows[:, 0]
    b = rows[:, 1]
    return ((a > x) & (b < y)).astype(jnp.int32)


def hash_buckets(keys, bucket_mask):
    """Multiplicative hash -> bucket id (paper §5.5 KVS).

    keys: [B] int32; bucket_mask: () int32 = nbuckets-1 (power of two).
    Returns [B] int32 bucket ids.
    """
    h = (keys.astype(jnp.int32) * HASH_MULT).astype(jnp.int32)
    # xor-fold the high half down so low bits depend on all 32 bits
    h = jnp.bitwise_xor(h, jnp.right_shift(h.astype(jnp.uint32), 16).astype(jnp.int32))
    return jnp.bitwise_and(h, bucket_mask)


def regex_mask_table(chars, table, accept):
    """DFA evaluation by table lookup (the CPU-shaped formulation).

    chars:  [B, L] int32 in 0..255
    table:  [S, 256] int32 next-state table
    accept: [S] int32 0/1
    Returns [B] int32 0/1 'string contains a match' (the DFA is built with
    a .*-style start loop and absorbing accept states, see redfa.py).
    """
    b = chars.shape[0]
    state = jnp.zeros((b,), dtype=jnp.int32)
    for t in range(chars.shape[1]):
        state = table[state, chars[:, t]]
    return accept[state]


def regex_mask_onehot(chars, tmat, accept_vec):
    """DFA evaluation as one-hot state x per-character transition-matrix
    products — the MXU-shaped formulation the Pallas kernel uses
    (DESIGN.md §2 Hardware-Adaptation).

    chars:      [B, L] int32
    tmat:       [256, S, S] f32, tmat[c, s, s'] = 1 iff delta(s, c) = s'
    accept_vec: [S] f32 0/1
    Returns [B] int32.
    """
    b = chars.shape[0]
    s = tmat.shape[1]
    state = jnp.zeros((b, s), dtype=jnp.float32).at[:, 0].set(1.0)
    for t in range(chars.shape[1]):
        m = tmat[chars[:, t]]  # [B, S, S]
        state = jnp.einsum("bs,bst->bt", state, m)
    return (state @ accept_vec > 0.5).astype(jnp.int32)
