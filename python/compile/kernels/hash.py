"""Layer-1 Pallas kernel: KVS bucket hashing (paper §5.5).

The FPGA pipelines a multiplicative hash per request; the TPU formulation
is a lane-vectorized multiply + xor-fold over a `[TILE]` i32 key block.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 2048

HASH_MULT = -1640531527  # 2654435761 wrapped to int32 (plain int: pallas
                         # kernels cannot capture jax-array constants)


def _kernel(mask_ref, keys_ref, out_ref):
    keys = keys_ref[...]
    h = (keys * HASH_MULT).astype(jnp.int32)
    h = jnp.bitwise_xor(h, jnp.right_shift(h.astype(jnp.uint32), 16).astype(jnp.int32))
    out_ref[...] = jnp.bitwise_and(h, mask_ref[0])


def hash_buckets(keys, bucket_mask):
    """keys: [B] i32, bucket_mask: [1] i32 (= nbuckets-1) -> [B] i32."""
    b = keys.shape[0]
    assert b % TILE == 0, f"batch {b} not a multiple of {TILE}"
    return pl.pallas_call(
        _kernel,
        grid=(b // TILE,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(bucket_mask, keys)
