"""Layer-1 Pallas kernel: SELECT predicate pushdown (paper §5.4).

Hardware adaptation (DESIGN.md §2): the paper's FPGA operator is a
per-row comparator pipeline. On a TPU the same data reduction is a
VMEM-tiled vector compare: each grid step streams one `[TILE, 32]` f32
row-block HBM->VMEM (16 KiB/block — double-buffered 32 KiB, far under
VMEM), evaluates the predicate across lanes, and writes a `[TILE]` i32
mask. `interpret=True` everywhere: the CPU PJRT client cannot execute
Mosaic custom-calls; real-TPU efficiency is estimated statically
(EXPERIMENTS.md §Perf).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 4096


def _kernel(x_ref, y_ref, rows_ref, out_ref):
    rows = rows_ref[...]  # [TILE, 32] f32
    a = rows[:, 0]
    b = rows[:, 1]
    x = x_ref[0]
    y = y_ref[0]
    out_ref[...] = ((a > x) & (b < y)).astype(jnp.int32)


def select_mask(rows, x, y):
    """rows: [B, 32] f32, x/y: [1] f32 -> [B] i32 mask. B % TILE == 0."""
    b = rows.shape[0]
    assert b % TILE == 0, f"batch {b} not a multiple of {TILE}"
    grid = (b // TILE,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),            # x
            pl.BlockSpec((1,), lambda i: (0,)),            # y
            pl.BlockSpec((TILE, rows.shape[1]), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(x, y, rows)
