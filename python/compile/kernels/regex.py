"""Layer-1 Pallas kernel: regex/DFA matching (paper §5.6).

Hardware adaptation (DESIGN.md §2): the paper's FPGA engine consumes one
character per cycle through an NFA circuit. A mechanical port would be a
scalar loop; instead we map the per-character step onto the MXU systolic
array: the DFA state is a one-hot f32 vector and each step is a batched
vector x transition-matrix product over the boolean semiring,

    state[B, S] <- state[B, S] @ T[c_t][S, S]

with `T` the per-character one-hot transition matrices ([256, S, S] f32,
1 MiB at S=32 — resident in VMEM across the whole string scan). The
batch is tiled `TILE_B` strings per grid step; `lax.fori_loop` walks the
string axis so the HLO stays a single fused loop instead of L unrolled
matmuls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 512
STATES = 32


def _kernel(chars_ref, tmat_ref, accept_ref, out_ref, *, length):
    chars = chars_ref[...]        # [TILE_B, L] i32
    tmat = tmat_ref[...]          # [256, S, S] f32
    accept = accept_ref[...]      # [S] f32
    b = chars.shape[0]
    s = tmat.shape[1]
    init = jnp.zeros((b, s), dtype=jnp.float32).at[:, 0].set(1.0)

    def step(t, state):
        m = tmat[chars[:, t]]                      # [TILE_B, S, S] gather
        return jnp.einsum("bs,bst->bt", state, m)  # MXU-shaped product

    state = jax.lax.fori_loop(0, length, step, init)
    out_ref[...] = (state @ accept > 0.5).astype(jnp.int32)


def regex_mask(chars, tmat, accept):
    """chars: [B, L] i32; tmat: [256, S, S] f32; accept: [S] f32 -> [B] i32."""
    b, length = chars.shape
    assert b % TILE_B == 0, f"batch {b} not a multiple of {TILE_B}"
    s = tmat.shape[1]
    return pl.pallas_call(
        functools.partial(_kernel, length=length),
        grid=(b // TILE_B,),
        in_specs=[
            pl.BlockSpec((TILE_B, length), lambda i: (i, 0)),
            pl.BlockSpec((256, s, s), lambda i: (0, 0, 0)),
            pl.BlockSpec((s,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(chars, tmat, accept)
