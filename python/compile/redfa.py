"""Regex -> DFA compiler for the FPGA regex operator (paper §5.6).

The paper integrates an open-source FPGA regex engine [Sidler et al.];
we need the equivalent build-time artifact: a regex compiled to a dense
DFA the kernels can evaluate. Pipeline:

    pattern --parse--> AST --Thompson--> NFA --subset--> DFA (<= S states)

Search semantics ("REGEXP LIKE", i.e. match anywhere in the string) are
baked in structurally: the NFA start state self-loops on every byte
(a ".*" prefix) and DFA accept states are absorbing (".*" suffix), so a
fixed-length scan over the whole 62-byte field answers "contains a
match". Pad bytes are NUL; patterns over printable characters therefore
behave as over the unpadded string.

Supported syntax: literals, '.', '*', '+', '?', '|', '(...)',
classes '[a-z0-9]' / negated '[^...]', escapes \\d \\w \\s \\. etc.

Outputs:
  * ``table``  [S, 256] int32 next-state (state 0 initial) — CPU form
  * ``accept`` [S] int32
  * ``tmat``   [256, S, S] float32 one-hot — MXU form
  * JSON export for the Rust side (operators/regex_op.rs loads it).
"""

import json

import numpy as np

ALPHABET = 256


# --------------------------------------------------------------------------
# Parsing: recursive descent to a tiny AST.
# --------------------------------------------------------------------------

class _Parser:
    def __init__(self, pattern: str):
        self.p = pattern
        self.i = 0

    def peek(self):
        return self.p[self.i] if self.i < len(self.p) else None

    def take(self):
        c = self.peek()
        self.i += 1
        return c

    def parse(self):
        node = self.alternation()
        if self.peek() is not None:
            raise ValueError(f"unexpected {self.peek()!r} at {self.i} in {self.p!r}")
        return node

    def alternation(self):
        branches = [self.concat()]
        while self.peek() == "|":
            self.take()
            branches.append(self.concat())
        return ("alt", branches) if len(branches) > 1 else branches[0]

    def concat(self):
        parts = []
        while self.peek() not in (None, "|", ")"):
            parts.append(self.repeat())
        if not parts:
            return ("empty",)
        return ("cat", parts) if len(parts) > 1 else parts[0]

    def repeat(self):
        node = self.atom()
        while self.peek() in ("*", "+", "?"):
            op = self.take()
            node = ({"*": "star", "+": "plus", "?": "opt"}[op], node)
        return node

    def atom(self):
        c = self.take()
        if c is None:
            raise ValueError("unexpected end of pattern")
        if c == "(":
            node = self.alternation()
            if self.take() != ")":
                raise ValueError("unbalanced parenthesis")
            return node
        if c == "[":
            return ("class", self.char_class())
        if c == ".":
            return ("class", frozenset(range(ALPHABET)))
        if c == "\\":
            return ("class", escape_class(self.take()))
        if c in "*+?)|":
            raise ValueError(f"misplaced {c!r}")
        return ("class", frozenset([ord(c)]))

    def char_class(self):
        negate = False
        if self.peek() == "^":
            self.take()
            negate = True
        chars: set[int] = set()
        first = True
        while True:
            c = self.take()
            if c is None:
                raise ValueError("unterminated character class")
            if c == "]" and not first:
                break
            first = False
            if c == "\\":
                chars |= escape_class(self.take())
                continue
            if self.peek() == "-" and self.p[self.i + 1 : self.i + 2] not in ("]", ""):
                self.take()  # '-'
                hi = self.take()
                chars |= set(range(ord(c), ord(hi) + 1))
            else:
                chars.add(ord(c))
        if negate:
            return frozenset(set(range(ALPHABET)) - chars)
        return frozenset(chars)


def escape_class(c):
    if c is None:
        raise ValueError("dangling escape")
    if c == "d":
        return frozenset(range(ord("0"), ord("9") + 1))
    if c == "w":
        s = set(range(ord("a"), ord("z") + 1)) | set(range(ord("A"), ord("Z") + 1))
        s |= set(range(ord("0"), ord("9") + 1)) | {ord("_")}
        return frozenset(s)
    if c == "s":
        return frozenset(map(ord, " \t\r\n\f\v"))
    return frozenset([ord(c)])


# --------------------------------------------------------------------------
# Thompson construction.
# --------------------------------------------------------------------------

class Nfa:
    def __init__(self):
        self.eps: list[set[int]] = []
        self.edges: list[dict[int, set[int]]] = []  # state -> char -> {next}

    def new_state(self) -> int:
        self.eps.append(set())
        self.edges.append({})
        return len(self.eps) - 1

    def add_eps(self, a, b):
        self.eps[a].add(b)

    def add_edge(self, a, chars, b):
        for c in chars:
            self.edges[a].setdefault(c, set()).add(b)


def _build(nfa: Nfa, node) -> tuple[int, int]:
    """Return (entry, exit) states for an AST node."""
    kind = node[0]
    if kind == "empty":
        s = nfa.new_state()
        return s, s
    if kind == "class":
        a, b = nfa.new_state(), nfa.new_state()
        nfa.add_edge(a, node[1], b)
        return a, b
    if kind == "cat":
        first_in, prev_out = _build(nfa, node[1][0])
        for part in node[1][1:]:
            pin, pout = _build(nfa, part)
            nfa.add_eps(prev_out, pin)
            prev_out = pout
        return first_in, prev_out
    if kind == "alt":
        a, b = nfa.new_state(), nfa.new_state()
        for branch in node[1]:
            bin_, bout = _build(nfa, branch)
            nfa.add_eps(a, bin_)
            nfa.add_eps(bout, b)
        return a, b
    if kind in ("star", "opt", "plus"):
        inner_in, inner_out = _build(nfa, node[1])
        a, b = nfa.new_state(), nfa.new_state()
        nfa.add_eps(a, inner_in)
        nfa.add_eps(inner_out, b)
        if kind in ("star", "opt"):
            nfa.add_eps(a, b)
        if kind in ("star", "plus"):
            nfa.add_eps(inner_out, inner_in)
        return a, b
    raise AssertionError(kind)


def _eps_closure(nfa: Nfa, states: frozenset[int]) -> frozenset[int]:
    stack = list(states)
    seen = set(states)
    while stack:
        s = stack.pop()
        for t in nfa.eps[s]:
            if t not in seen:
                seen.add(t)
                stack.append(t)
    return frozenset(seen)


class Dfa:
    """Dense DFA with search semantics baked in."""

    def __init__(self, table: np.ndarray, accept: np.ndarray, pattern: str):
        self.table = table    # [S, 256] int32
        self.accept = accept  # [S] int32
        self.pattern = pattern

    @property
    def n_states(self) -> int:
        return self.table.shape[0]

    def matches(self, data: bytes) -> bool:
        s = 0
        for ch in data:
            s = int(self.table[s, ch])
        return bool(self.accept[s])

    def onehot_tmat(self, padded_states: int | None = None) -> np.ndarray:
        """[256, S, S] f32 one-hot transition matrices (optionally padded
        to a fixed state count for the AOT kernel)."""
        s = padded_states or self.n_states
        assert s >= self.n_states
        t = np.zeros((ALPHABET, s, s), dtype=np.float32)
        for st in range(self.n_states):
            for c in range(ALPHABET):
                t[c, st, self.table[st, c]] = 1.0
        # padding states self-loop (unreachable; keeps the product stochastic)
        for st in range(self.n_states, s):
            t[:, st, st] = 1.0
        return t

    def accept_vec(self, padded_states: int | None = None) -> np.ndarray:
        s = padded_states or self.n_states
        v = np.zeros((s,), dtype=np.float32)
        v[: self.n_states] = self.accept.astype(np.float32)
        return v

    def to_json(self) -> str:
        return json.dumps(
            {
                "pattern": self.pattern,
                "n_states": self.n_states,
                "table": self.table.flatten().tolist(),
                "accept": self.accept.tolist(),
            }
        )


def compile_regex(pattern: str, max_states: int = 32) -> Dfa:
    """Compile to a search-semantics DFA with at most `max_states` states."""
    ast = _Parser(pattern).parse()
    nfa = Nfa()
    entry, exit_ = _build(nfa, ast)
    # search semantics: start self-loops on any byte (".*" prefix)
    start = nfa.new_state()
    nfa.add_eps(start, entry)
    nfa.add_edge(start, range(ALPHABET), start)
    accept_nfa = exit_

    # subset construction
    start_set = _eps_closure(nfa, frozenset([start]))
    index: dict[frozenset[int], int] = {start_set: 0}
    worklist = [start_set]
    rows: list[list[int]] = []
    accept: list[int] = []
    matched_sink = None  # absorbing accept state id, created lazily

    while worklist:
        cur = worklist.pop(0)
        rows.append([0] * ALPHABET)
        accept.append(1 if accept_nfa in cur else 0)
        row = rows[index[cur]]
        if accept_nfa in cur:
            # absorbing accept (".*" suffix): once matched, stay matched
            row[:] = [index[cur]] * ALPHABET
            continue
        for c in range(ALPHABET):
            nxt = set()
            for s in cur:
                nxt |= nfa.edges[s].get(c, set())
            nxt = _eps_closure(nfa, frozenset(nxt))
            if accept_nfa in nxt:
                # collapse all accepting subsets into one absorbing state
                if matched_sink is None:
                    sink = frozenset([accept_nfa])
                    if sink not in index:
                        index[sink] = len(index)
                        worklist.append(sink)
                    matched_sink = index[sink]
                row[c] = matched_sink
                continue
            if nxt not in index:
                if len(index) >= max_states:
                    raise ValueError(
                        f"pattern {pattern!r} needs more than {max_states} DFA states"
                    )
                index[nxt] = len(index)
                worklist.append(nxt)
            row[c] = index[nxt]

    table = np.array(rows, dtype=np.int32)
    return Dfa(table, np.array(accept, dtype=np.int32), pattern)


def from_json(text: str) -> Dfa:
    d = json.loads(text)
    table = np.array(d["table"], dtype=np.int32).reshape(d["n_states"], ALPHABET)
    return Dfa(table, np.array(d["accept"], dtype=np.int32), d["pattern"])
