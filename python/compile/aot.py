"""AOT compile the Layer-2 graphs to HLO **text** artifacts.

HLO text — not ``lowered.compile().serialize()`` and not the proto —
is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python python/compile/aot.py --out artifacts

Writes one ``<op>.hlo.txt`` per operator plus ``manifest.json`` recording
shapes/dtypes (consumed by rust/src/runtime/artifacts.rs) and the HLO
cost summary used by the L2 perf notes in EXPERIMENTS.md.
"""

import argparse
import hashlib
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
from jax._src.lib import xla_client as xc

from compile.model import OPS, example_args
from compile.kernels.ref import BATCH, DFA_STATES, ROW_WORDS, STR_LEN


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_of(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "geometry": {
            "batch": BATCH,
            "row_words": ROW_WORDS,
            "str_len": STR_LEN,
            "dfa_states": DFA_STATES,
        },
        "ops": {},
    }
    for name, fn in OPS.items():
        ex = example_args()[name]
        lowered = jax.jit(fn).lower(*ex)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        out_avals = [spec_of(x) for x in jax.tree_util.tree_leaves(lowered.out_info)]
        manifest["ops"][name] = {
            "file": fname,
            "inputs": [spec_of(s) for s in ex],
            "outputs": out_avals,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "hlo_bytes": len(text),
        }
        print(f"wrote {fname}: {len(text)} chars, "
              f"{len(ex)} inputs -> {len(out_avals)} outputs")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['ops'])} ops)")


if __name__ == "__main__":
    main()
