"""Layer-2 JAX operator graphs (build-time only; never on the request path).

Each function is the complete compute graph for one smart-memory-controller
operator's datapath, calling the Layer-1 Pallas kernels. `aot.py` lowers
them once to HLO text; the Rust coordinator loads and executes the
artifacts through PJRT (rust/src/runtime).

Shapes are fixed at AOT time (PJRT executables are monomorphic): batch
4096 rows/keys/strings per invocation; the Rust side pads final batches.
"""

import jax.numpy as jnp

from .kernels import hash as hash_kernel
from .kernels import regex as regex_kernel
from .kernels import select as select_kernel
from .kernels.ref import BATCH, DFA_STATES, ROW_WORDS, STR_LEN


def select_op(rows, x, y):
    """SELECT pushdown datapath: [B, 32] f32 rows -> [B] i32 match mask
    plus the running match count (the operator's FIFO fill accounting).
    """
    mask = select_kernel.select_mask(rows, x, y)
    # PERF: the result-FIFO slot assignment (exclusive cumsum) was lowered
    # by the runtime's XLA 0.5.1 backend as a serial 4096-step loop and
    # dominated batch time; the coordinator derives slots from the mask on
    # the Rust side instead (EXPERIMENTS.md §Perf).
    count = jnp.sum(mask)
    return mask, count


def regex_op(chars, tmat, accept):
    """Regex pushdown datapath: [B, 62] i32 strings -> mask/slots/count."""
    mask = regex_kernel.regex_mask(chars, tmat, accept)
    count = jnp.sum(mask)
    return mask, count


def hash_op(keys, bucket_mask):
    """KVS request hashing: [B] i32 keys -> [B] i32 bucket ids."""
    return (hash_kernel.hash_buckets(keys, bucket_mask),)


def example_args():
    """Example (abstract) arguments for AOT lowering, keyed by op name."""
    import jax

    f32 = jnp.float32
    i32 = jnp.int32
    return {
        "select": (
            jax.ShapeDtypeStruct((BATCH, ROW_WORDS), f32),
            jax.ShapeDtypeStruct((1,), f32),
            jax.ShapeDtypeStruct((1,), f32),
        ),
        "regex": (
            jax.ShapeDtypeStruct((BATCH, STR_LEN), i32),
            jax.ShapeDtypeStruct((256, DFA_STATES, DFA_STATES), f32),
            jax.ShapeDtypeStruct((DFA_STATES,), f32),
        ),
        "hash": (
            jax.ShapeDtypeStruct((BATCH,), i32),
            jax.ShapeDtypeStruct((1,), i32),
        ),
    }


OPS = {
    "select": select_op,
    "regex": regex_op,
    "hash": hash_op,
}
