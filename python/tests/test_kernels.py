"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and value distributions; integer outputs must
match exactly, float comparisons use allclose. This is the CORE
correctness signal for the compute layer — the Rust runtime executes the
same graphs AOT, and rust integration tests compare against the same
semantics re-implemented natively.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import redfa
from compile.kernels import hash as hash_kernel
from compile.kernels import ref
from compile.kernels import regex as regex_kernel
from compile.kernels import select as select_kernel

SETTINGS = dict(max_examples=20, deadline=None)


# --------------------------------------------------------------------------
# SELECT
# --------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n_tiles=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    x=st.floats(-100, 100, allow_nan=False, width=32),
    y=st.floats(-100, 100, allow_nan=False, width=32),
)
def test_select_matches_ref(n_tiles, seed, x, y):
    rng = np.random.default_rng(seed)
    b = select_kernel.TILE * n_tiles
    rows = rng.uniform(-100, 100, size=(b, ref.ROW_WORDS)).astype(np.float32)
    got = select_kernel.select_mask(
        jnp.asarray(rows), jnp.asarray([x], jnp.float32), jnp.asarray([y], jnp.float32)
    )
    want = ref.select_mask(jnp.asarray(rows), jnp.float32(x), jnp.float32(y))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_select_boundary_values_not_selected():
    # strict inequalities: a > X AND b < Y
    rows = np.zeros((select_kernel.TILE, ref.ROW_WORDS), np.float32)
    rows[:, 0] = 5.0
    rows[:, 1] = 3.0
    m = select_kernel.select_mask(
        jnp.asarray(rows), jnp.asarray([5.0], jnp.float32), jnp.asarray([3.0], jnp.float32)
    )
    assert int(np.asarray(m).sum()) == 0


# --------------------------------------------------------------------------
# HASH
# --------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n_tiles=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
    log2_buckets=st.integers(1, 24),
)
def test_hash_matches_ref(n_tiles, seed, log2_buckets):
    rng = np.random.default_rng(seed)
    b = hash_kernel.TILE * n_tiles
    keys = rng.integers(-(2**31), 2**31, size=(b,), dtype=np.int64).astype(np.int32)
    mask = np.int32((1 << log2_buckets) - 1)
    got = hash_kernel.hash_buckets(jnp.asarray(keys), jnp.asarray([mask], jnp.int32))
    want = ref.hash_buckets(jnp.asarray(keys), jnp.int32(mask))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).min() >= 0
    assert np.asarray(got).max() <= mask


def test_hash_spreads_sequential_keys():
    # multiplicative hashing must decorrelate dense key ranges
    b = hash_kernel.TILE
    keys = np.arange(b, dtype=np.int32)
    mask = np.int32(255)
    got = np.asarray(hash_kernel.hash_buckets(jnp.asarray(keys), jnp.asarray([mask], jnp.int32)))
    counts = np.bincount(got, minlength=256)
    assert counts.max() < 4 * b / 256, f"bucket skew too high: {counts.max()}"


# --------------------------------------------------------------------------
# REGEX
# --------------------------------------------------------------------------

def _random_strings(rng, n, alphabet=b"abc01 "):
    out = np.zeros((n, ref.STR_LEN), dtype=np.int32)
    for i in range(n):
        ln = rng.integers(0, ref.STR_LEN + 1)
        s = rng.choice(list(alphabet), size=ln)
        out[i, :ln] = s
    return out


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), pattern=st.sampled_from([
    "abc",
    "a+b",
    "a(b|c)*",
    "[ab]+c",
    "a.c",
    "(0|1)+",
    "ab?c",
]))
def test_regex_kernel_matches_table_ref_and_onehot_ref(seed, pattern):
    rng = np.random.default_rng(seed)
    dfa = redfa.compile_regex(pattern, max_states=ref.DFA_STATES)
    chars = _random_strings(rng, regex_kernel.TILE_B)
    tmat = jnp.asarray(dfa.onehot_tmat(ref.DFA_STATES))
    accept = jnp.asarray(dfa.accept_vec(ref.DFA_STATES))
    got = np.asarray(regex_kernel.regex_mask(jnp.asarray(chars), tmat, accept))
    want_oh = np.asarray(ref.regex_mask_onehot(jnp.asarray(chars), tmat, accept))
    want_tbl = np.asarray(
        ref.regex_mask_table(
            jnp.asarray(chars), jnp.asarray(dfa.table), jnp.asarray(dfa.accept)
        )
    )
    np.testing.assert_array_equal(got, want_oh)
    np.testing.assert_array_equal(got, want_tbl)


def test_regex_kernel_finds_planted_matches():
    dfa = redfa.compile_regex("needle", max_states=ref.DFA_STATES)
    chars = np.zeros((regex_kernel.TILE_B, ref.STR_LEN), dtype=np.int32)
    # plant "needle" at various offsets in rows 0..9
    for i in range(10):
        s = b"x" * i + b"needle"
        chars[i, : len(s)] = np.frombuffer(s, dtype=np.uint8)
    got = np.asarray(
        regex_kernel.regex_mask(
            jnp.asarray(chars),
            jnp.asarray(dfa.onehot_tmat(ref.DFA_STATES)),
            jnp.asarray(dfa.accept_vec(ref.DFA_STATES)),
        )
    )
    assert got[:10].sum() == 10
    assert got[10:].sum() == 0
