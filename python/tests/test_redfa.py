"""redfa (regex -> DFA compiler) vs Python's `re` on search semantics."""

import os
import re
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import redfa

PATTERNS = [
    "abc",
    "a|b",
    "ab*c",
    "a+",
    "(ab)+",
    "a?b",
    "[abc]",
    "[a-c]x",
    "[^a]b",
    "a.c",
    "x(y|z)*w",
    r"\d\d",
    r"\w+",
    "a[0-9]+b",
    "(a|b)(c|d)",
]


def dfa_search(pattern: str, data: bytes) -> bool:
    return redfa.compile_regex(pattern).matches(data)


def re_search(pattern: str, data: bytes) -> bool:
    return re.search(pattern.encode(), data) is not None


@settings(max_examples=60, deadline=None)
@given(
    pattern=st.sampled_from(PATTERNS),
    data=st.binary(max_size=40),
)
def test_matches_python_re_on_random_bytes(pattern, data):
    assert dfa_search(pattern, data) == re_search(pattern, data), (pattern, data)


@settings(max_examples=60, deadline=None)
@given(
    pattern=st.sampled_from(PATTERNS),
    data=st.text(alphabet="abcxyz019 ", max_size=40),
)
def test_matches_python_re_on_text(pattern, data):
    b = data.encode()
    assert dfa_search(pattern, b) == re_search(pattern, b), (pattern, data)


def test_accept_states_are_absorbing():
    dfa = redfa.compile_regex("ab")
    # find an accepting state and check all its transitions self-loop
    for s in range(dfa.n_states):
        if dfa.accept[s]:
            assert (dfa.table[s] == s).all()


def test_match_anywhere_semantics():
    dfa = redfa.compile_regex("abc")
    assert dfa.matches(b"abc")
    assert dfa.matches(b"xxabcxx")
    assert dfa.matches(b"xxabc")
    assert not dfa.matches(b"ab c")
    assert not dfa.matches(b"")


def test_empty_matching_pattern_accepts_everything():
    dfa = redfa.compile_regex("a*")
    assert dfa.matches(b"")
    assert dfa.matches(b"zzz")


def test_state_budget_enforced():
    import pytest

    with pytest.raises(ValueError):
        # forces exponential subset blowup past 32 states
        redfa.compile_regex("(a|b)*a(a|b)(a|b)(a|b)(a|b)(a|b)(a|b)", max_states=32)


def test_json_round_trip():
    dfa = redfa.compile_regex("a(b|c)+d")
    clone = redfa.from_json(dfa.to_json())
    np.testing.assert_array_equal(dfa.table, clone.table)
    np.testing.assert_array_equal(dfa.accept, clone.accept)
    for s in [b"abd", b"abcbcd", b"ad", b"xxacdyy"]:
        assert dfa.matches(s) == clone.matches(s)


def test_onehot_padding_is_stochastic():
    dfa = redfa.compile_regex("ab")
    t = dfa.onehot_tmat(32)
    assert t.shape == (256, 32, 32)
    # every row of every per-char matrix sums to exactly 1
    sums = t.sum(axis=2)
    np.testing.assert_allclose(sums, np.ones_like(sums))
