"""Layer-2 / AOT checks: operator graphs compose correctly, artifacts are
regenerable, and the lowered HLO executes with the same results as the
eager graphs (i.e. what Rust will run via PJRT is what we tested)."""

import json
import os
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

from compile import redfa
from compile.kernels.ref import BATCH, DFA_STATES, ROW_WORDS, STR_LEN
from compile.model import OPS, example_args, hash_op, regex_op, select_op

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def test_select_op_mask_and_count():
    rng = np.random.default_rng(0)
    rows = rng.uniform(-10, 10, size=(BATCH, ROW_WORDS)).astype(np.float32)
    mask, count = select_op(
        jnp.asarray(rows), jnp.asarray([0.0], jnp.float32), jnp.asarray([5.0], jnp.float32)
    )
    mask = np.asarray(mask)
    want = ((rows[:, 0] > 0.0) & (rows[:, 1] < 5.0)).astype(np.int32)
    np.testing.assert_array_equal(mask, want)
    assert int(count) == int(want.sum())


def test_regex_op_end_to_end():
    dfa = redfa.compile_regex("er+or", max_states=DFA_STATES)
    chars = np.zeros((BATCH, STR_LEN), dtype=np.int32)
    hits = [3, 999, 4000]
    for i in hits:
        s = b"xx errror yy"
        chars[i, : len(s)] = np.frombuffer(s, dtype=np.uint8)
    mask, count = regex_op(
        jnp.asarray(chars),
        jnp.asarray(dfa.onehot_tmat(DFA_STATES)),
        jnp.asarray(dfa.accept_vec(DFA_STATES)),
    )
    assert int(count) == len(hits)
    assert sorted(np.flatnonzero(np.asarray(mask)).tolist()) == hits


def test_hash_op_shapes():
    keys = np.arange(BATCH, dtype=np.int32)
    (buckets,) = hash_op(jnp.asarray(keys), jnp.asarray([1023], jnp.int32))
    assert buckets.shape == (BATCH,)
    assert int(np.asarray(buckets).max()) <= 1023


def test_every_op_lowers_to_hlo_text():
    for name, fn in OPS.items():
        lowered = jax.jit(fn).lower(*example_args()[name])
        from compile.aot import to_hlo_text

        text = to_hlo_text(lowered)
        assert "HloModule" in text, name
        # pallas interpret mode must have produced plain HLO, not
        # Mosaic/custom-call stubs the CPU PJRT client cannot run
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower(), name


def test_aot_writes_manifest_and_artifacts():
    with tempfile.TemporaryDirectory() as td:
        subprocess.run(
            [sys.executable, os.path.join(REPO, "python/compile/aot.py"), "--out", td],
            check=True,
            capture_output=True,
        )
        with open(os.path.join(td, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["geometry"]["batch"] == BATCH
        assert set(manifest["ops"]) == {"select", "regex", "hash"}
        for name, op in manifest["ops"].items():
            path = os.path.join(td, op["file"])
            assert os.path.exists(path), name
            text = open(path).read()
            assert len(text) == op["hlo_bytes"]
            assert "HloModule" in text


def test_lowered_select_executes_like_eager():
    """Compile the artifact the way rust does (HLO text -> executable) and
    compare numerics against the eager path."""
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(select_op).lower(*example_args()["select"])
    from compile.aot import to_hlo_text

    text = to_hlo_text(lowered)
    # round-trip through text exactly as the rust loader does
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
    )
    assert comp.as_hlo_text() == text

    rng = np.random.default_rng(7)
    rows = rng.uniform(-10, 10, size=(BATCH, ROW_WORDS)).astype(np.float32)
    x = np.asarray([1.0], np.float32)
    y = np.asarray([2.0], np.float32)
    eager_mask, eager_count = select_op(
        jnp.asarray(rows), jnp.asarray(x), jnp.asarray(y)
    )
    compiled = jax.jit(select_op).lower(
        jnp.asarray(rows), jnp.asarray(x), jnp.asarray(y)
    ).compile()
    got_mask, got_count = compiled(jnp.asarray(rows), jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_array_equal(np.asarray(got_mask), np.asarray(eager_mask))
    assert int(got_count) == int(eager_count)
